"""Strategy-architecture co-exploration (ISSUE 9, DESIGN.md §13): the
joint (architecture, Strategy) search dimension end to end — pinned
evaluation replays the grid argmin bit-exactly, derived caps unlock
pp > 64 on deep models, the v2 memory model is recompute/schedule-aware,
joint campaigns run + resume bit-identically, and exported train configs
pass the `repro.dist` shardability gate and the real launcher."""
import dataclasses

import numpy as np
import pytest

from repro.core.compiler import (Strategy, derived_strategy_caps,
                                 enumerate_strategies, strategy_memory_need)
from repro.core.design_space import (JointDesign, StrategySpace, WSCDesign,
                                     decode, sample)
from repro.core.evaluator import (clear_eval_cache, evaluate_design_batch,
                                  evaluate_joint_batch)
from repro.core.validator import validate, validate_joint_batch
from repro.core.workload import GPT_BENCHMARKS
from repro.explore import Campaign, CampaignSpec, FidelitySchedule
from repro.explore.export import (export_train_config, load_train_config,
                                  train_argv, validate_train_config)

WL = GPT_BENCHMARKS[0]                                   # GPT-1.7B train


def _designs(n=32, seed=11):
    rng = np.random.default_rng(seed)
    return [r.design for r in (validate(decode(u)) for u in sample(rng, n))
            if r.ok]


def joint_spec(**over) -> CampaignSpec:
    kw = dict(
        name="t-joint", workload="GPT-1.7B", scenario="train",
        strategy="mfmobo", strategy_mode="joint",
        fidelity=FidelitySchedule(f1="analytical", f0="analytical",
                                  d1=2, d0=2, k=2),
        n_evals_f0=5, n_evals_f1=6, q=2, n_candidates=16,
        max_strategies=6, seed=7)
    kw.update(over)
    return CampaignSpec(**kw)


# ------------------- pinned evaluation vs the strategy grid -----------------


def test_joint_pinned_replays_grid_argmin_bit_exact():
    """Pinning each design to its own grid-argmin strategy through the
    joint path must reproduce the grid-mode objectives bit-for-bit — the
    contract that makes joint and grid hypervolumes comparable."""
    designs = _designs()
    assert len(designs) >= 8
    clear_eval_cache()
    grid = evaluate_design_batch(designs, WL, max_strategies=8)
    pts = [JointDesign(d, r.strategy)
           for d, r in zip(designs, grid) if r.feasible]
    assert pts, "expected feasible grid evaluations"
    joint = evaluate_joint_batch(pts, WL, max_strategies=8)
    for g, j in zip([r for r in grid if r.feasible], joint):
        assert j.feasible
        assert j.throughput == g.throughput          # bitwise, not approx
        assert j.power_w == g.power_w
        assert j.strategy == g.strategy
        assert j.n_wafers == g.n_wafers


def test_joint_batch_is_cached():
    designs = _designs(n=16)[:4]
    strat = Strategy(tp=2, pp=2, dp=2, microbatches=2)
    pts = [JointDesign(d, strat) for d in designs]
    clear_eval_cache()
    a = evaluate_joint_batch(pts, WL, max_strategies=8)
    b = evaluate_joint_batch(pts, WL, max_strategies=8)
    assert [(r.throughput, r.feasible) for r in a] == \
        [(r.throughput, r.feasible) for r in b]
    # a different pinned strategy must not collide in the cache
    pts2 = [JointDesign(d, Strategy(tp=4, pp=1, dp=2, microbatches=2))
            for d in designs]
    c = evaluate_joint_batch(pts2, WL, max_strategies=8)
    assert any(x.throughput != y.throughput
               for x, y in zip(a, c) if x.feasible and y.feasible) or \
        all(x.strategy != y.strategy for x, y in zip(a, c))


# ------------------- derived caps: pp > 64 on deep models -------------------


def test_deep_workload_can_use_pp_over_64():
    """The historical pp <= 64 magic cap is gone: a 128-layer model admits
    pp = 128 both in the derived caps and in actual enumeration."""
    wl128 = dataclasses.replace(WL, n_layers=128)
    caps = derived_strategy_caps(wl128, 1 << 19)
    assert caps["pp"] == 128 > 64
    d = validate(WSCDesign()).design
    ss = enumerate_strategies(d, wl128)
    assert any(s.pp == 128 for s in ss)
    # the joint encoding reaches it too: encode/decode round-trips pp=128
    space = StrategySpace.for_workload(wl128, 1 << 19)
    s = Strategy(tp=2, pp=128, dp=2, microbatches=2)
    assert space.decode_strategy(space.encode_strategy(s)).pp == 128
    ok = validate_joint_batch([JointDesign(d, s)], wl128)[0]
    assert ok.reason != "strategy_pp"


def test_caps_scale_with_cores_and_layers():
    caps_small = derived_strategy_caps(WL, 256)
    assert caps_small["tp"] == 256 and caps_small["pp"] == 16  # 24 layers
    assert caps_small["ep"] == 1                               # dense
    moe = dataclasses.replace(WL, moe_experts=8)
    assert derived_strategy_caps(moe, 256)["ep"] == 8


# ------------------- v2 memory model regression -----------------------------


def test_memory_model_counts_activations_and_optimizer():
    """Regression for the PR 2 memory check: the optimizer multiplier and
    the activation term are both present — the frozen grid formula
    (weights-only) strictly underestimates a training footprint."""
    p = WL.params_bytes()
    need = float(strategy_memory_need(WL, tp=1, pp=1, dp=1, mb=1))
    assert need > 6.0 * p                 # weights*opt_mult plus activations
    frozen = 1 * p * 6.0 / 1              # the legacy grid-mode check
    assert need > frozen


def test_memory_model_recompute_and_schedule():
    # recompute keeps only the stage-boundary activation per resident layer
    full = float(strategy_memory_need(WL, 1, 2, 1, 8, recompute=False))
    rc = float(strategy_memory_need(WL, 1, 2, 1, 8, recompute=True))
    assert rc < full
    # GPipe keeps all mb microbatches in flight; 1F1B at most pp
    f1b = float(strategy_memory_need(WL, 1, 2, 1, 8, gpipe=False))
    gp = float(strategy_memory_need(WL, 1, 2, 1, 8, gpipe=True))
    assert gp > f1b
    # expert parallelism shards MoE expert weights
    moe = dataclasses.replace(WL, moe_experts=8)
    if moe.expert_params_bytes() > 0:
        assert float(strategy_memory_need(moe, 1, 1, 1, 1, ep=8)) < \
            float(strategy_memory_need(moe, 1, 1, 1, 1, ep=1))


# ------------------- joint validation verdicts ------------------------------


def test_validate_joint_batch_verdicts():
    d = validate(WSCDesign()).design
    wl_tiny = dataclasses.replace(WL, seq=1)     # tokens_per_step == batch
    pts = [
        JointDesign(d, Strategy(tp=2, pp=2, dp=2, microbatches=2)),    # ok
        JointDesign(d, Strategy(tp=1, pp=32, dp=1, microbatches=1)),   # pp>L
        JointDesign(d, Strategy(tp=1, pp=1, dp=1, microbatches=1,
                                ep=2)),          # dense model, ep > 1
        JointDesign(d, Strategy(tp=1, pp=1, dp=512,
                                microbatches=32)),  # over-splits the step
    ]
    out = validate_joint_batch(pts, wl_tiny)
    assert out[0].ok
    assert (not out[1].ok) and out[1].reason == "strategy_pp"
    assert (not out[2].ok) and out[2].reason == "strategy_ep_experts"
    assert (not out[3].ok) and out[3].reason == "strategy_tokens"


def test_validate_joint_batch_resource_verdicts():
    """The search-side resource gate: joint validation rejects strategies
    that can never fit the area-matched system — more cells than cores,
    footprints over SRAM+DRAM capacity, or a dp x mb split that doesn't
    divide the global batch (the grid's own divisibility constraint)."""
    d = validate(WSCDesign()).design
    pts = [
        JointDesign(d, Strategy(tp=1 << 18, pp=1, dp=1, microbatches=1)),
        JointDesign(d, Strategy(tp=1, pp=1, dp=512, microbatches=1)),
        JointDesign(d, Strategy(tp=1, pp=1, dp=1, microbatches=3)),
    ]
    out = validate_joint_batch(pts, WL)
    assert (not out[0].ok) and out[0].reason == "strategy_cores"
    assert (not out[1].ok) and out[1].reason == "strategy_memory"
    assert (not out[2].ok) and out[2].reason == "strategy_batch_div"


def test_validate_joint_batch_schedule_recompute_are_live():
    """schedule/recompute change verdicts, not just the score: at a
    sequence length where activations dominate, GPipe (all microbatches in
    flight) blows the memory budget that 1F1B (at most pp in flight) fits,
    and recompute buys the GPipe point back — both axes present real
    feasibility trade-offs to the joint search."""
    d = validate(WSCDesign()).design
    wl_long = dataclasses.replace(WL, seq=1 << 16)
    pts = [
        JointDesign(d, Strategy(1, 2, 1, 8)),
        JointDesign(d, Strategy(1, 2, 1, 8, schedule="gpipe")),
        JointDesign(d, Strategy(1, 2, 1, 8, schedule="gpipe",
                                recompute=True)),
    ]
    out = validate_joint_batch(pts, wl_long, n_wafers=1)
    assert out[0].ok                                      # 1F1B fits
    assert (not out[1].ok) and out[1].reason == "strategy_memory"
    assert out[2].ok                                      # recompute unlocks


@pytest.mark.parametrize("compiled", ["1", "0"])
def test_joint_eval_rejects_impossible_pinned(monkeypatch, compiled):
    """The evaluation-side resource gate: a pinned strategy the grid's own
    enumeration arithmetic would never admit (cores or the frozen memory
    check) comes back infeasible with reason "strategy_resources" — on the
    compiled and the NumPy reference pipelines alike."""
    monkeypatch.setenv("REPRO_COMPILED_EVAL", compiled)
    d = validate(WSCDesign()).design
    pts = [JointDesign(d, Strategy(tp=1 << 18, pp=1, dp=1, microbatches=1)),
           JointDesign(d, Strategy(tp=1, pp=1, dp=512, microbatches=1))]
    clear_eval_cache()
    out = evaluate_joint_batch(pts, WL, max_strategies=8)
    assert all(not r.feasible for r in out)
    assert all(r.reason == "strategy_resources" for r in out)
    assert all(r.throughput == 0.0 for r in out)


# ------------------- joint campaigns: run / resume / spec -------------------


def test_joint_spec_json_roundtrip_and_grid_dict_unchanged():
    spec = joint_spec()
    again = CampaignSpec.from_json(spec.to_json())
    assert again == spec and again.strategy_mode == "joint"
    # grid-mode specs serialize without the new keys, so pre-joint JSON
    # artifacts stay byte-identical
    grid = joint_spec(strategy_mode="grid")
    d = grid.to_dict()
    assert "strategy_mode" not in d and "strategy_space" not in d
    with pytest.raises(ValueError, match="strategy_mode"):
        joint_spec(strategy_mode="best").validate()
    with pytest.raises(ValueError):
        joint_spec(scenario="serving").validate()


def test_joint_campaign_runs_and_front_carries_strategies():
    clear_eval_cache()
    res = Campaign(joint_spec()).run()
    assert res.finished
    spec = joint_spec()
    assert res.n_evals == spec.n_evals_f0 + spec.n_evals_f1
    assert res.hv_final > 0
    # every evaluated point is a JointDesign and the front records the
    # pinned strategy in its describe string
    assert all(isinstance(p, JointDesign) for p in res.trace.designs)
    assert all("tp=" in p["describe"] and "pp=" in p["describe"]
               for p in res.front)


def test_joint_checkpoint_resume_bit_identical(tmp_path):
    ck = str(tmp_path / "joint.ckpt.pkl")
    clear_eval_cache()
    full = Campaign(joint_spec()).run()
    clear_eval_cache()
    partial = Campaign(joint_spec()).run(checkpoint_path=ck, max_steps=2)
    assert not partial.finished
    resumed = Campaign.resume(ck).run(checkpoint_path=ck)
    assert resumed.finished
    assert [tuple(y) for y in resumed.trace.ys] == \
        [tuple(y) for y in full.trace.ys]
    assert resumed.trace.hv == full.trace.hv
    assert resumed.trace.designs == full.trace.designs


# ------------------- export: DSE winner -> runnable train config ------------


def test_export_validates_every_shipped_arch():
    s = Strategy(tp=2, pp=1, dp=2, microbatches=1)
    from repro.configs import ARCH_IDS
    for arch in sorted(ARCH_IDS):
        cfg = export_train_config(s, arch, batch=8, seq=64, reduced=True)
        ok, why = validate_train_config(cfg)
        assert ok, f"{arch}: {why}"


def test_export_rejects_bad_arithmetic_and_arch():
    s = Strategy(tp=1, pp=1, dp=3, microbatches=1)
    cfg = export_train_config(s, "smollm-135m", batch=8, seq=32)
    assert validate_train_config(cfg) == (False, "dp_batch_divide")
    cfg = export_train_config(
        Strategy(tp=1, pp=1, dp=2, microbatches=3), "smollm-135m",
        batch=8, seq=32)
    assert validate_train_config(cfg) == (False, "microbatch_divide")
    with pytest.raises(ValueError, match="unknown arch"):
        export_train_config(s, "gpt-nonesuch")


def test_export_roundtrip_and_launcher_dryrun(tmp_path):
    """An exported config must be accepted by the real production
    launcher: `train.main(train_argv(cfg))` runs the reduced arch on a
    1-device mesh to completion."""
    from repro.launch import train as launch_train

    d = validate(WSCDesign()).design
    point = JointDesign(d, Strategy(tp=1, pp=1, dp=1, microbatches=1))
    path = str(tmp_path / "export.json")
    cfg = export_train_config(point, "smollm-135m", steps=2, batch=2,
                              seq=32, reduced=True, path=path)
    loaded = load_train_config(path)
    assert loaded == cfg
    ok, why = validate_train_config(loaded)
    assert ok, why
    out = launch_train.main(train_argv(loaded)
                            + ["--ckpt-dir", str(tmp_path / "ck"),
                               "--log-every", "100"])
    assert [m["step"] for m in out["metrics"]] == [0, 1]
    assert np.isfinite([m["loss"] for m in out["metrics"]]).all()
