"""Fig. 13 + §IX-F reproduction: the overall DSE on GPT-175B training —
design-space scatter (stacked vs off-chip DRAM Pareto fronts) and the
headline comparison of searched Pareto-optimal WSCs vs the H100-like GPU
cluster and WSE2-like / Dojo-like WSC baselines at matched total area.

The scatter sweep runs through `evaluate_objectives_batch` (one vectorized
pass over all sampled designs) and the MFMOBO refinement is a declarative
campaign — the shipped `examples/campaigns/gpt175b_train_dse.json` spec,
shrunk in quick mode — run through `repro.explore.Campaign`;
candidates/sec is reported for the perf trajectory.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict

import numpy as np

from benchmarks.common import sample_valid_designs, save_artifact
from repro.core.baselines import DOJO_LIKE, WSE2_LIKE, gpu_cluster_eval
from repro.core.evaluator import evaluate_design, evaluate_objectives_batch
from repro.core.pareto import pareto_front, to_max_space
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS, inference_workload
from repro.explore import Campaign, CampaignSpec

SPEC_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples", "campaigns",
    "gpt175b_train_dse.json")


def refinement_spec(quick: bool) -> CampaignSpec:
    """The MFMOBO refinement campaign: the shipped example spec as-is, or a
    CI-sized shrink of it (smaller workload + budget, same schedule)."""
    spec = CampaignSpec.from_json(SPEC_PATH)
    if quick:
        spec = dataclasses.replace(
            spec, name=spec.name + "-quick", workload=GPT_BENCHMARKS[1].name,
            n_evals_f0=6, n_evals_f1=8, q=2)
    return spec


def run(quick: bool = False) -> Dict:
    wl = GPT_BENCHMARKS[1] if quick else GPT_BENCHMARKS[7]

    # explore (analytical fidelity for this scatter; fig8 shows MF behavior)
    n = 24 if quick else 80
    t0 = time.time()
    designs = sample_valid_designs(n, seed=13)
    pts = []
    for d, (t, p) in zip(designs, evaluate_objectives_batch(designs, wl)):
        if t > 0:
            pts.append({"throughput": t, "power_w": p,
                        "stacked": d.use_stacked_dram,
                        "design": d.describe()})
    # a short MFMOBO refinement campaign to densify the front
    spec = refinement_spec(quick)
    res = Campaign(spec).run()
    tr = res.trace
    for d, y in zip(tr.designs, tr.ys):
        if y[0] > 0:
            pts.append({"throughput": y[0], "power_w": y[1],
                        "stacked": d.use_stacked_dram,
                        "design": d.describe()})
    wall_s = time.time() - t0
    n_evals = n + tr.n_evals

    def front_of(sub):
        if not sub:
            return []
        arr = to_max_space([r["throughput"] for r in sub],
                           [r["power_w"] for r in sub])
        mask = [tuple(a) for a in pareto_front(arr)]
        return [r for r, a in zip(sub, arr) if tuple(a) in set(mask)]

    stacked = front_of([r for r in pts if r["stacked"]])
    offchip = front_of([r for r in pts if not r["stacked"]])

    # baselines at matched area
    gpu_t, gpu_p = gpu_cluster_eval(wl)
    base = {}
    for name, d in (("WSE2-like", WSE2_LIKE), ("Dojo-like", DOJO_LIKE)):
        v = validate(d)
        r = evaluate_design(v.design if v.ok else d, wl, max_strategies=8)
        base[name] = {"throughput": r.throughput, "power_w": r.power_w}
    base["H100-like"] = {"throughput": gpu_t, "power_w": gpu_p}

    best = max(pts, key=lambda r: r["throughput"])
    # perf gain at same-or-lower power; power gain at same-or-higher perf
    def perf_gain(ref):
        cand = [r for r in pts if r["power_w"] <= ref["power_w"]]
        if not cand:
            return 0.0
        return max(r["throughput"] for r in cand) / ref["throughput"] - 1.0

    def power_gain(ref):
        cand = [r for r in pts if r["throughput"] >= ref["throughput"]]
        if not cand:
            return 0.0
        return 1.0 - min(r["power_w"] for r in cand) / ref["power_w"]

    out = {
        "workload": wl.name,
        "n_points": len(pts),
        "n_evaluations": n_evals,
        "wall_s": wall_s,
        "candidates_per_sec": n_evals / max(wall_s, 1e-9),
        "campaigns": {spec.name: {
            "candidates_per_sec": res.candidates_per_sec,
            "wall_s": res.wall_s, "n_evals": res.n_evals,
            "hv_final": res.hv_final,
            "stage_cache": res.stage_cache}},
        "pareto_stacked": stacked,
        "pareto_offchip": offchip,
        "baselines": base,
        "best_design": best,
        "gains": {name: {"perf_pct": 100 * perf_gain(ref),
                         "power_pct": 100 * power_gain(ref)}
                  for name, ref in base.items()},
    }
    save_artifact("fig13_dse", out)
    print(f"\n=== Fig.13: DSE for {wl.name} training ===")
    print(f"sampled {len(pts)} feasible designs; Pareto: "
          f"{len(stacked)} stacked-DRAM, {len(offchip)} off-chip "
          f"({out['candidates_per_sec']:.2f} candidates/sec)")
    for name, ref in base.items():
        g = out["gains"][name]
        print(f"  vs {name:10s}: thpt {ref['throughput']:12.0f} tok/s, "
              f"power {ref['power_w']/1e3:8.1f} kW -> searched gains: "
              f"perf +{g['perf_pct']:.0f}% | power -{g['power_pct']:.0f}%")
    print(f"best searched: {best['design']}")
    print(f"  thpt {best['throughput']:.0f} tok/s  power {best['power_w']/1e3:.1f} kW")
    return out


if __name__ == "__main__":
    run()
