"""Shared benchmark utilities: artifact IO, GNN corpus building/training,
design sampling."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def save_artifact(name: str, data: Dict):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return path


def load_artifact(name: str):
    path = os.path.join(ART_DIR, name + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def sample_valid_designs(n: int, seed: int = 0, **decode_kw) -> List:
    from repro.core.design_space import decode_batch, sample
    from repro.core.validator import validate

    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        for d in decode_batch(sample(rng, n), **decode_kw):
            r = validate(d)
            if r.ok:
                out.append(r.design)
            if len(out) >= n:
                break
    return out


_GNN_CACHE = {}


def trained_gnn(n_designs: int = 8, epochs: int = 40, seed: int = 0,
                quick: bool = False):
    """Train (and memoize) the GNN congestion model on noc_sim traces, with
    a held-out validation split: the returned info records per-epoch train
    loss plus validation loss / Kendall-tau so downstream consumers (and
    the online calibration loop) can judge checkpoint quality."""
    key = (n_designs, epochs, seed, quick)
    if key in _GNN_CACHE:
        return _GNN_CACHE[key]
    import jax

    from repro.core.calibration import build_calibration_set
    from repro.core.noc_gnn import init_gnn, train_gnn
    from repro.core.workload import GPT_BENCHMARKS

    if quick:
        n_designs, epochs = 4, 10
    designs = sample_valid_designs(n_designs, seed=seed)
    dataset = []
    for wl in (GPT_BENCHMARKS[0], GPT_BENCHMARKS[2]):
        dataset.extend(build_calibration_set(designs, wl))
    params = init_gnn(jax.random.PRNGKey(seed))
    t0 = time.time()
    params, hist = train_gnn(params, dataset, epochs=epochs, val_frac=0.2,
                             patience=max(epochs // 4, 3))
    info = {"n_graphs": len(dataset), "train_s": time.time() - t0,
            "loss_first": hist.train_loss[0],
            "loss_last": hist.train_loss[-1],
            # metrics of the checkpoint actually returned (best epoch)
            "val_loss": hist.best_val_loss,
            "val_kendall_tau": hist.best_val_kendall_tau,
            "best_epoch": hist.best_epoch,
            "stopped_epoch": hist.stopped_epoch}
    _GNN_CACHE[key] = (params, info)
    return params, info


def kendall_tau(a: np.ndarray, b: np.ndarray, **kw) -> float:
    """Kendall rank correlation. Thin lazy wrapper over the canonical
    vectorized implementation in repro.core.noc_gnn — imported at call time
    so that jax-free consumers of this module (e.g. roofline_table) don't
    pay the jax import at startup."""
    from repro.core.noc_gnn import kendall_tau as _kt
    return _kt(a, b, **kw)
