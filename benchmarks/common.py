"""Shared benchmark utilities: artifact IO, GNN corpus building/training,
design sampling."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Tuple

import numpy as np

ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")


def save_artifact(name: str, data: Dict):
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return path


def load_artifact(name: str):
    path = os.path.join(ART_DIR, name + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def sample_valid_designs(n: int, seed: int = 0, **decode_kw) -> List:
    from repro.core.design_space import decode_batch, sample
    from repro.core.validator import validate

    rng = np.random.default_rng(seed)
    out = []
    while len(out) < n:
        for d in decode_batch(sample(rng, n), **decode_kw):
            r = validate(d)
            if r.ok:
                out.append(r.design)
            if len(out) >= n:
                break
    return out


_GNN_CACHE = {}


def trained_gnn(n_designs: int = 8, epochs: int = 40, seed: int = 0,
                quick: bool = False):
    """Train (and memoize) the GNN congestion model on noc_sim traces."""
    key = (n_designs, epochs, seed, quick)
    if key in _GNN_CACHE:
        return _GNN_CACHE[key]
    import jax

    from repro.core.compiler import compile_chunk
    from repro.core.noc_gnn import featurize_transfer, init_gnn, train_gnn
    from repro.core.workload import GPT_BENCHMARKS

    if quick:
        n_designs, epochs = 4, 10
    designs = sample_valid_designs(n_designs, seed=seed)
    dataset = []
    for wl in (GPT_BENCHMARKS[0], GPT_BENCHMARKS[2]):
        for d in designs:
            for tp, mbt in ((16, 4096), (64, 1024)):
                g = compile_chunk(d, wl, tp=tp, mb_tokens=mbt,
                                  cores_per_chunk=64)
                for t in range(len(g.transfers)):
                    if g.transfers[t].pairs:
                        dataset.append(
                            featurize_transfer(g, d, t, with_target=True))
    params = init_gnn(jax.random.PRNGKey(seed))
    t0 = time.time()
    params, losses = train_gnn(params, dataset, epochs=epochs)
    info = {"n_graphs": len(dataset), "train_s": time.time() - t0,
            "loss_first": losses[0], "loss_last": losses[-1]}
    _GNN_CACHE[key] = (params, info)
    return params, info


def kendall_tau(a: np.ndarray, b: np.ndarray) -> float:
    """Kendall rank correlation (O(n^2), fine for benchmark sizes)."""
    a, b = np.asarray(a), np.asarray(b)
    n = len(a)
    num = 0
    den = 0
    for i in range(n):
        for j in range(i + 1, n):
            sa = np.sign(a[i] - a[j])
            sb = np.sign(b[i] - b[j])
            if sa and sb:
                num += int(sa == sb) - int(sa != sb)
                den += 1
    return num / max(den, 1)
