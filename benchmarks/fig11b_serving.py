"""Fig. 11(b) extension: request-level serving evaluation for GPT-175B.

The paper's headline inference numbers come from serving workloads, but the
per-figure benchmarks score isolated prefill/decode steps. This benchmark
runs the request-level continuous-batching model (repro.core.serving,
DESIGN.md §8) end to end:

  (1) a design sweep scored on (SLO goodput, power) — the serving Pareto
      front, with the SLO calibrated from the sampled designs' median
      TTFT/TPOT so it binds for roughly half the pool;
  (2) an SLO-constrained MOBO exploration as a declarative serving
      campaign (repro.explore, DESIGN.md §9): the calibrated SLO becomes
      `ConstraintSpec`s on TTFT/TPOT, so violating candidates are mapped to
      the penalty point and excluded from the front;
  (3) the heterogeneity re-score: the same prefill/decode disaggregation as
      Fig. 12, under the coupled request model instead of rate matching.

Artifacts land in benchmarks/artifacts/fig11b_serving.json; the goodput
front + explorer stats are tracked in BENCH_dse.json.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import sample_valid_designs, save_artifact
from repro.core.design_space import WSCDesign
from repro.core.heterogeneity import evaluate_hetero_serving
from repro.core.pareto import pareto_front, to_max_space
from repro.core.serving import ServingSLO, evaluate_serving_batch
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS, RequestMix
from repro.explore import (
    Campaign,
    CampaignSpec,
    ConstraintSpec,
    FidelitySchedule,
    ServingSpec,
)


def explorer_spec(workload: str, mix: RequestMix, slo: ServingSLO,
                  slots: int, quick: bool) -> CampaignSpec:
    """The SLO-constrained exploration as a campaign: the probe-calibrated
    SLO lands both in the goodput objective (via the serving spec) and as
    hard TTFT/TPOT constraints."""
    return CampaignSpec(
        name="fig11b-serving-slo", workload=workload, scenario="serving",
        strategy="mobo",
        constraints=(ConstraintSpec("ttft", "<=", slo.ttft_s),
                     ConstraintSpec("tpot", "<=", slo.tpot_s)),
        fidelity=FidelitySchedule(f0="analytical", d0=4, k=0),
        n_evals_f0=8 if quick else 20, q=4, seed=3,
        max_strategies=8,
        serving=ServingSpec(
            n_requests=mix.n_requests,
            prompt_len=int(mix.prompt_lens[0]),
            out_len=int(mix.out_lens[0]), slots=slots,
            ttft_s=slo.ttft_s, tpot_s=slo.tpot_s))


def run(quick: bool = False) -> Dict:
    wl = GPT_BENCHMARKS[7]                          # GPT-175B
    n_req, out_len = (16, 64) if quick else (32, 256)
    mix = RequestMix.uniform(n_req, prompt_len=2048, out_len=out_len)
    slots = 8

    # ---- (1) design sweep + SLO calibration ----------------------------
    designs = sample_valid_designs(12 if quick else 48, seed=11)
    probe = evaluate_serving_batch(designs, wl, mix, ServingSLO(1e9, 1e9),
                                   slots=slots, max_strategies=8)
    feas = [r for r in probe if r.feasible]
    if not feas:
        raise RuntimeError("no feasible serving design in the probe pool")
    slo = ServingSLO(
        ttft_s=float(np.median([r.ttft_s for r in feas])),
        tpot_s=float(np.median([r.tpot_s for r in feas])))
    scored = evaluate_serving_batch(designs, wl, mix, slo, slots=slots,
                                    max_strategies=8)
    rows = [{"goodput_tok_s": r.goodput_tok_s, "power_w": r.power_w,
             "ttft_s": r.ttft_s, "tpot_s": r.tpot_s,
             "slo_attainment": r.slo_attainment, "n_wafers": r.n_wafers}
            for r in scored if r.feasible]
    # zero-goodput designs are feasible but serve nothing within the SLO —
    # they would pad the front with useless lowest-power points
    good = np.array([r["goodput_tok_s"] for r in rows
                     if r["goodput_tok_s"] > 0])
    pw = np.array([max(r["power_w"], 1.0) for r in rows
                   if r["goodput_tok_s"] > 0])
    front_pts = pareto_front(to_max_space(good, pw))   # (goodput, -power)
    front = [{"goodput_tok_s": float(t), "power_w": float(-p)}
             for t, p in front_pts]

    # ---- (2) SLO-constrained exploration (campaign) --------------------
    spec = explorer_spec(wl.name, mix, slo, slots, quick)
    res = Campaign(spec).run()
    tr = res.trace
    explored_best = max((y[0] for y in tr.ys), default=0.0)

    # ---- (3) heterogeneity, coupled request model ----------------------
    d_prefill = validate(WSCDesign(
        dataflow="WS", mac_num=1024, buffer_kb=256, buffer_bw=1024,
        noc_bw=512, core_array=(10, 10), inter_reticle_bw_ratio=1.0,
        use_stacked_dram=True, dram_bw_tbps_per_100mm2=0.5,
        reticle_array=(8, 8), integration="infosow")).design
    d_decode = validate(WSCDesign(
        dataflow="WS", mac_num=256, buffer_kb=128, buffer_bw=1024,
        noc_bw=512, core_array=(9, 9), inter_reticle_bw_ratio=1.0,
        use_stacked_dram=True, dram_bw_tbps_per_100mm2=2.0,
        reticle_array=(8, 8), integration="infosow")).design
    hetero = []
    for gran in ("core", "reticle", "wafer"):
        dp = d_decode if gran == "core" else d_prefill
        h = evaluate_hetero_serving(dp, d_decode, wl, gran, 0.5, mix, slo,
                                    slots=slots, n_wafers=8)
        hetero.append({"granularity": gran,
                       "goodput_tok_s": h.goodput_tok_s,
                       "ttft_s": h.ttft_s, "tpot_s": h.tpot_s,
                       "slo_attainment": h.slo_attainment,
                       "kv_transfer_s": h.kv_transfer_s})

    out = {
        "workload": wl.name,
        "mix": {"n_requests": mix.n_requests, "prompt_len": 2048,
                "out_len": out_len, "slots": slots},
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "sweep": rows,
        "serving_front": front,
        "goodput_best": float(good.max()) if len(good) else 0.0,
        "explorer": {"n_evals": tr.n_evals, "hv_final":
                     tr.hv[-1] if tr.hv else 0.0,
                     "goodput_best": explored_best,
                     "campaign": spec.name,
                     "candidates_per_sec": res.candidates_per_sec,
                     "wall_s": res.wall_s,
                     "n_constraint_violations":
                     res.objective_stats["f0"]["n_constraint_violations"],
                     "front_size": len(res.front)},
        "stage_cache": res.stage_cache,
        "hetero_serving": hetero,
    }
    save_artifact("fig11b_serving", out)

    print("\n=== Fig.11b: request-level serving (GPT-175B) ===")
    print(f"mix: {mix.n_requests} req x (prompt 2048 -> {out_len} tokens), "
          f"{slots} slots; SLO ttft<={slo.ttft_s:.3f}s tpot<={slo.tpot_s:.4f}s")
    print(f"sweep: {len(rows)} feasible, goodput/power front "
          f"({len(front)} points), best goodput {out['goodput_best']:.0f} tok/s")
    for p in front:
        print(f"  front: goodput={p['goodput_tok_s']:10.1f} tok/s  "
              f"power={p['power_w']:10.0f} W")
    print(f"explorer: {tr.n_evals} SLO-constrained evals, "
          f"best goodput {explored_best:.0f} tok/s")
    for h in hetero:
        print(f"hetero {h['granularity']:8s}: goodput={h['goodput_tok_s']:9.1f}"
              f" ttft={h['ttft_s']:7.3f}s tpot={h['tpot_s']:.4f}s "
              f"att={h['slo_attainment']:.2f}")
    return out


if __name__ == "__main__":
    run()
