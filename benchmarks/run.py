"""Benchmark harness: one benchmark per paper table/figure + the roofline
table from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ("fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
           "roofline")

_MODULES = {
    "fig7": "benchmarks.fig7_eval_models",
    "fig8": "benchmarks.fig8_explorer",
    "fig9": "benchmarks.fig9_core_granularity",
    "fig10": "benchmarks.fig10_reticle_granularity",
    "fig11": "benchmarks.fig11_inference",
    "fig12": "benchmarks.fig12_heterogeneity",
    "fig13": "benchmarks.fig13_dse",
    "roofline": "benchmarks.roofline_table",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sample counts (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)

    failures = []
    for name in names:
        mod_name = _MODULES[name.strip()]
        print(f"\n{'='*70}\nRunning {mod_name} (quick={args.quick})\n{'='*70}",
              flush=True)
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            mod.run(quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.0f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print("\nFAILED:", failures)
        sys.exit(1)
    print("\nAll benchmarks completed.")


if __name__ == "__main__":
    main()
