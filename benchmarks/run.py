"""Benchmark harness: one benchmark per paper table/figure + the roofline
table from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,...]

Every invocation also measures the batched-vs-serial evaluator speedup and
writes `BENCH_dse.json` at the repo root (per-benchmark wall time, explorer
candidates/sec, key result metrics) so the perf trajectory is tracked
across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

BENCHES = ("fig7", "fig8", "fig9", "fig10", "fig11", "fig11b", "fig11c",
           "fig12", "fig13", "roofline")

_MODULES = {
    "fig7": "benchmarks.fig7_eval_models",
    "fig8": "benchmarks.fig8_explorer",
    "fig9": "benchmarks.fig9_core_granularity",
    "fig10": "benchmarks.fig10_reticle_granularity",
    "fig11": "benchmarks.fig11_inference",
    "fig11b": "benchmarks.fig11b_serving",
    "fig11c": "benchmarks.fig11c_trace_serving",
    "fig12": "benchmarks.fig12_heterogeneity",
    "fig13": "benchmarks.fig13_dse",
    "roofline": "benchmarks.roofline_table",
}

# result keys worth tracking across PRs (when a benchmark reports them).
# "campaigns" / "stage_cache" carry per-campaign wall-clock, candidates/sec
# and per-fidelity-stage eval-cache hit-rates (DESIGN.md §9) so campaign
# cost — including the f1->f0 handover — is visible in BENCH_dse.json.
_TRACKED_KEYS = ("candidates_per_sec", "n_evaluations", "wall_s", "q",
                 "convergence_speedup_vs_mobo", "hv_improvement_at_equal_iters",
                 "hv_sim_final", "calibration", "batched_candidates_per_sec",
                 "n_points", "workload", "eval_cache",
                 "serving_front", "goodput_best", "slo", "explorer",
                 "hetero_serving", "campaigns", "stage_cache", "fleet",
                 "eval_lanes", "trace_serving", "chat_slo")

BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_dse.json")


def measure_batch_speedup(n_designs: int = 64, max_strategies: int = 24,
                          serial_subset: int = 8):
    """Acceptance probe, one record per registered fidelity backend:
    evaluate_design_batch on n_designs candidates vs serial evaluate_design
    calls (cold caches for both), on the quick GPT-1.7B workload.

    The analytical serial loop runs all n_designs; the gnn/sim serial loops
    are slow enough that they run a `serial_subset` prefix and extrapolate
    candidates/sec (recorded as n_designs_serial). Agreement is always
    checked on the designs both paths evaluated."""
    import jax

    from benchmarks.common import sample_valid_designs
    from repro.core.evaluator import (clear_eval_cache, evaluate_design,
                                      evaluate_design_batch)
    from repro.core.noc_gnn import init_gnn
    from repro.core.workload import GPT_BENCHMARKS

    from repro.core import eval_compiled

    wl = GPT_BENCHMARKS[0]
    designs = sample_valid_designs(n_designs, seed=1234)
    gnn_params = init_gnn(jax.random.PRNGKey(0))
    # pre-compile the analytical evaluator buckets (DESIGN.md §12) so the
    # timed analytical batch measures the jitted pipeline, not its compile
    eval_compiled.warm_evaluator_kernels(wl, n_designs_max=n_designs,
                                         max_strategies=max_strategies)
    # warm the jitted GNN kernels so the probe times steady-state math, not
    # one-off XLA compilation (which the serial path amortizes too). The
    # warm-up must run the FULL design batch: smaller prefixes miss the
    # larger pow-2 feature buckets / grid patterns the timed batch hits,
    # leaving recompilation inside the timed region.
    evaluate_design_batch(designs, wl, fidelity="gnn",
                          gnn_params=gnn_params,
                          max_strategies=max_strategies)
    [evaluate_design(d, wl, fidelity="gnn", gnn_params=gnn_params,
                     max_strategies=max_strategies) for d in designs[:1]]

    out = {}
    for fidelity in ("analytical", "gnn", "sim"):
        kw = {"gnn_params": gnn_params} if fidelity == "gnn" else {}
        n_serial = n_designs if fidelity == "analytical" else serial_subset
        clear_eval_cache()
        t0 = time.perf_counter()
        serial = [evaluate_design(d, wl, fidelity=fidelity,
                                  max_strategies=max_strategies, **kw)
                  for d in designs[:n_serial]]
        serial_s = time.perf_counter() - t0
        clear_eval_cache()
        t0 = time.perf_counter()
        batch = evaluate_design_batch(designs, wl, fidelity=fidelity,
                                      max_strategies=max_strategies, **kw)
        batch_s = time.perf_counter() - t0
        agree = all(
            a.feasible == b.feasible
            and (not a.feasible
                 or abs(a.throughput - b.throughput)
                 <= 1e-5 * abs(a.throughput))
            for a, b in zip(serial, batch))
        cps_serial = n_serial / max(serial_s, 1e-9)
        cps_batch = n_designs / max(batch_s, 1e-9)
        out[fidelity] = {
            "n_designs": n_designs,
            "n_designs_serial": n_serial,
            "workload": wl.name,
            "serial_s": serial_s,
            "batch_s": batch_s,
            "speedup": cps_batch / max(cps_serial, 1e-9),
            "candidates_per_sec_batch": cps_batch,
            "candidates_per_sec_serial": cps_serial,
            "scalar_batch_agree": agree,
        }
    return out


def measure_proposal_rate(n_obs: int = 16, n_candidates: int = 96,
                          q: int = 4, iters: int = 20):
    """Optimizer-only acceptance probe: one full MFMOBO proposal iteration
    = GP pair refit on the observation set + greedy q-EHVI acquisition over
    the candidate pool (posterior predict + EHVI + q rank-1 fantasizations),
    with evaluation excluded — i.e. the jitted hot path of DESIGN.md §10.
    Kernels are warmed first so the probe times steady-state proposals, not
    XLA compilation."""
    import numpy as np

    from repro.core.design_space import DIMS
    from repro.core.mfmobo import (_acquire_batch, _fit_models, hv_ref,
                                   obj_space, warm_optimizer_kernels)

    warm_optimizer_kernels(n_obs, n_candidates=n_candidates, q=q)
    rng = np.random.default_rng(7)
    X = rng.random((n_obs, len(DIMS)))
    Y = np.stack([1e3 * (1.0 + rng.random(n_obs)),
                  1e3 * (2.0 + rng.random(n_obs))], 1)
    ev = obj_space([tuple(y) for y in Y])
    ref = hv_ref(15000.0)
    cands = rng.random((iters, n_candidates, len(DIMS)))
    t0 = time.perf_counter()
    for i in range(iters):
        models = _fit_models(X, Y)
        _acquire_batch(models, cands[i], ev, ref, q=q)
    wall = time.perf_counter() - t0
    return {
        "n_obs": n_obs,
        "n_candidates": n_candidates,
        "q": q,
        "iters": iters,
        "wall_s": wall,
        "proposals_per_sec": iters / max(wall, 1e-9),
    }


def measure_fused_iteration_rate(n_obs: int = 16, n_candidates: int = 96,
                                 q: int = 4, iters: int = 20):
    """Fused-iteration acceptance probe (DESIGN.md §12): one synchronous
    MFMOBO f1 iteration end to end — GP pair refit, scanned q-EHVI acquire,
    candidate decode, and compiled analytical evaluation of the q picks
    gathered by device-resident indices (no host sync between proposal and
    evaluation). Kernels (optimizer AND evaluator) are warmed first, so the
    probe times the steady-state fused loop."""
    import numpy as np

    from repro.core import eval_compiled
    from repro.core.design_space import DIMS, decode_batch
    from repro.core.evaluator import clear_eval_cache, evaluate_pool_fused
    from repro.core.mfmobo import (_acquire_batch_device, _fit_models,
                                   hv_ref, obj_space, warm_optimizer_kernels)
    from repro.core.workload import GPT_BENCHMARKS

    if not eval_compiled.enabled():
        return {"status": "disabled"}
    wl = GPT_BENCHMARKS[0]
    warm_optimizer_kernels(n_obs, n_candidates=n_candidates, q=q,
                           workload=wl, n_designs_max=q)
    rng = np.random.default_rng(7)
    X = rng.random((n_obs, len(DIMS)))
    Y = np.stack([1e3 * (1.0 + rng.random(n_obs)),
                  1e3 * (2.0 + rng.random(n_obs))], 1)
    ev = obj_space([tuple(y) for y in Y])
    ref = hv_ref(15000.0)
    cands = rng.random((iters, n_candidates, len(DIMS)))
    clear_eval_cache()
    t0 = time.perf_counter()
    for i in range(iters):
        models = _fit_models(X, Y)
        cand_d = decode_batch(cands[i])
        js_dev = _acquire_batch_device(models, cands[i], ev, ref, q=q)
        evaluate_pool_fused(cand_d, wl, js_dev, q)
    wall = time.perf_counter() - t0
    return {
        "n_obs": n_obs,
        "n_candidates": n_candidates,
        "q": q,
        "iters": iters,
        "wall_s": wall,
        "iterations_per_sec": iters / max(wall, 1e-9),
        "candidates_per_sec": iters * q / max(wall, 1e-9),
        "eval_lanes": eval_compiled.lane_stats(),
    }


def measure_joint_vs_grid(seed: int = 2, n0: int = 8, n1: int = 10,
                          q: int = 2, n_candidates: int = 32):
    """Strategy-architecture co-exploration acceptance probe (DESIGN.md
    §13): two MFMOBO campaigns on the GPT-175B train workload with the
    same seed and budget — one scoring each design at the argmin of the
    frozen per-design strategy grid (`strategy_mode="grid"`), one
    searching the joint (architecture, Strategy) space
    (`strategy_mode="joint"`). Records both final hypervolumes, plus a
    bit-exactness check that the joint pinned-evaluation path replays the
    grid run's winning strategies to identical objectives (the contract
    that makes the two hypervolumes comparable at all)."""
    from repro.core.design_space import JointDesign
    from repro.core.evaluator import (clear_eval_cache, evaluate_design_batch,
                                      evaluate_joint_batch)
    from repro.explore import Campaign, CampaignSpec, FidelitySchedule
    from repro.explore.campaign import resolve_workload

    def mk(mode):
        return CampaignSpec(
            name=f"joint-vs-grid-{mode}", workload="GPT-175B",
            scenario="train", strategy="mfmobo",
            fidelity=FidelitySchedule(f1="analytical", f0="analytical",
                                      d1=2, d0=2, k=2),
            n_evals_f0=n0, n_evals_f1=n1, q=q, n_candidates=n_candidates,
            seed=seed, strategy_mode=mode)

    out = {"workload": "GPT-175B", "seed": seed,
           "n_evals_f0": n0, "n_evals_f1": n1, "q": q}
    runs = {}
    for mode in ("grid", "joint"):
        clear_eval_cache()
        t0 = time.perf_counter()
        runs[mode] = Campaign(mk(mode)).run()
        out[f"hv_{mode}"] = float(runs[mode].hv_final)
        out[f"wall_s_{mode}"] = time.perf_counter() - t0
    # replay contract: pinning each grid-evaluated design to its own grid
    # argmin strategy through the joint path must reproduce the grid
    # objectives bit-for-bit
    wl = resolve_workload(mk("grid"))
    designs = list(runs["grid"].trace.designs)
    grid_r = evaluate_design_batch(designs, wl)
    pts = [JointDesign(d, r.strategy)
           for d, r in zip(designs, grid_r) if r.feasible]
    joint_r = evaluate_joint_batch(pts, wl)
    out["pinned_matches_grid"] = bool(pts) and all(
        b.feasible and a.throughput == b.throughput
        for a, b in zip([r for r in grid_r if r.feasible], joint_r))
    out["n_replayed"] = len(pts)
    return out


def write_bench_json(records, quick: bool, speedup, optimizer=None,
                     fused=None, joint_vs_grid=None):
    # merge into the existing file so an `--only` subset run refreshes its
    # own records without wiping the other benchmarks' tracked history
    merged = {}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                merged = json.load(f).get("benchmarks", {})
        except (json.JSONDecodeError, OSError):
            merged = {}
    merged.update(records)
    data = {
        "generated_unix_s": time.time(),
        "quick": quick,
        "batch_eval": speedup,
        "optimizer": optimizer or {"status": "failed"},
        "fused_iteration": fused or {"status": "failed"},
        "joint_vs_grid": joint_vs_grid or {"status": "failed"},
        "benchmarks": merged,
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(data, f, indent=1, default=float)
    return BENCH_JSON


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sample counts (CI-speed)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)

    failures = []
    records = {}
    for name in names:
        mod_name = _MODULES[name.strip()]
        print(f"\n{'='*70}\nRunning {mod_name} (quick={args.quick})\n{'='*70}",
              flush=True)
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(mod_name)
            result = mod.run(quick=args.quick)
            wall = time.time() - t0
            rec = {"wall_s": wall, "status": "ok", "quick": args.quick}
            if isinstance(result, dict):
                rec["metrics"] = {k: result[k] for k in _TRACKED_KEYS
                                  if k in result}
            records[name] = rec
            print(f"[{name}] done in {wall:.0f}s", flush=True)
        except Exception:
            traceback.print_exc()
            records[name] = {"wall_s": time.time() - t0, "status": "failed",
                             "quick": args.quick}
            failures.append(name)

    print(f"\n{'='*70}\nMeasuring batched-evaluator speedup (all fidelities)"
          f"\n{'='*70}", flush=True)
    try:
        speedup = measure_batch_speedup()
        for fid, rec in speedup.items():
            print(f"{fid:12s}: {rec['n_designs']} designs in "
                  f"{rec['batch_s']:.3f}s batched -> {rec['speedup']:.0f}x "
                  f"vs serial ({rec['candidates_per_sec_batch']:.0f} "
                  f"candidates/sec batched, "
                  f"{rec['candidates_per_sec_serial']:.1f} serial)")
            if not rec["scalar_batch_agree"]:
                print(f"{fid} batch eval DISAGREES with serial evaluation")
                failures.append(f"batch_vs_serial_agreement_{fid}")
        if speedup["gnn"]["speedup"] < 20.0:
            print("gnn batched speedup below the 20x acceptance floor")
            failures.append("gnn_batch_speedup_floor")
    except Exception:
        traceback.print_exc()
        speedup = {"status": "failed"}
        failures.append("batch_speedup")

    print(f"\n{'='*70}\nMeasuring compiled-optimizer proposal rate"
          f"\n{'='*70}", flush=True)
    try:
        optimizer = measure_proposal_rate()
        print(f"optimizer   : {optimizer['iters']} proposal iterations "
              f"(refit + q={optimizer['q']} acquire over "
              f"{optimizer['n_candidates']} candidates) in "
              f"{optimizer['wall_s']:.3f}s -> "
              f"{optimizer['proposals_per_sec']:.1f} proposals/sec")
        if optimizer["proposals_per_sec"] < 2.0:
            print("optimizer proposal rate below the 2/sec acceptance floor")
            failures.append("optimizer_proposal_rate_floor")
    except Exception:
        traceback.print_exc()
        optimizer = {"status": "failed"}
        failures.append("proposal_rate")

    print(f"\n{'='*70}\nMeasuring fused propose->evaluate iteration rate"
          f"\n{'='*70}", flush=True)
    try:
        fused = measure_fused_iteration_rate()
        if fused.get("status") == "disabled":
            print("compiled evaluator disabled (REPRO_COMPILED_EVAL=0); "
                  "fused probe skipped")
        else:
            print(f"fused       : {fused['iters']} fused iterations "
                  f"(refit + q={fused['q']} acquire + compiled analytical "
                  f"eval) in {fused['wall_s']:.3f}s -> "
                  f"{fused['candidates_per_sec']:.1f} evaluated "
                  f"candidates/sec")
            if fused["candidates_per_sec"] < 8.0:
                print("fused-iteration candidates/sec below the 8/sec "
                      "acceptance floor")
                failures.append("fused_iteration_rate_floor")
    except Exception:
        traceback.print_exc()
        fused = {"status": "failed"}
        failures.append("fused_iteration_rate")

    print(f"\n{'='*70}\nMeasuring joint-vs-grid strategy co-exploration"
          f"\n{'='*70}", flush=True)
    try:
        jvg = measure_joint_vs_grid()
        print(f"joint-vs-grid [{jvg['workload']}, seed {jvg['seed']}]: "
              f"grid hv={jvg['hv_grid']:.2f} "
              f"({jvg['wall_s_grid']:.0f}s)  joint hv={jvg['hv_joint']:.2f} "
              f"({jvg['wall_s_joint']:.0f}s)  pinned replay "
              f"{'matches' if jvg['pinned_matches_grid'] else 'DIVERGES'} "
              f"({jvg['n_replayed']} points)")
        if not jvg["pinned_matches_grid"]:
            print("joint pinned path does not replay the grid argmin "
                  "strategies bit-exactly")
            failures.append("joint_pinned_replay_mismatch")
        if jvg["hv_joint"] < jvg["hv_grid"]:
            print("joint-campaign hypervolume below the grid-campaign floor")
            failures.append("joint_vs_grid_hv_floor")
    except Exception:
        traceback.print_exc()
        jvg = {"status": "failed"}
        failures.append("joint_vs_grid")

    # fleet acceptance floors (DESIGN.md §11): the fig8 fleet probe must
    # sustain a minimum evaluated-candidate rate and the warm second pass
    # over the persistent eval cache must actually hit it
    fleet = (records.get("fig8", {}).get("metrics", {}) or {}).get("fleet")
    if fleet:
        if fleet["fleet_candidates_per_sec"] < 0.2:
            print("fleet candidates/sec below the 0.2/sec acceptance floor")
            failures.append("fleet_candidates_per_sec_floor")
        if fleet["warm_f0_hit_rate"] <= 0.5:
            print("warm-fleet f0 cache hit-rate below the 50% floor "
                  f"({100 * fleet['warm_f0_hit_rate']:.0f}%)")
            failures.append("fleet_warm_cache_hit_rate_floor")

    # trace-serving acceptance floors (DESIGN.md §14): the spike trace must
    # produce positive worst-window interactive goodput somewhere, and some
    # non-FIFO policy must beat FIFO at equal power (same design)
    tsv = (records.get("fig11c", {}).get("metrics", {}) or {}) \
        .get("trace_serving")
    if tsv:
        if tsv["worst_window_goodput_best"] <= 0.0:
            print("trace-serving worst-window goodput floor violated "
                  "(no design/policy sustains chat goodput through the spike)")
            failures.append("trace_serving_goodput_floor")
        if not tsv["policy_beats_fifo"]:
            print("no non-FIFO policy beats FIFO on worst-window goodput "
                  "at equal power")
            failures.append("trace_serving_policy_vs_fifo_floor")

    path = write_bench_json(records, args.quick, speedup, optimizer, fused,
                            jvg)
    print(f"wrote {path}")

    if failures:
        print("\nFAILED:", failures)
        sys.exit(1)
    print("\nAll benchmarks completed.")


if __name__ == "__main__":
    main()
