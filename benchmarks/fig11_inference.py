"""Fig. 11 reproduction: LLM inference speedup over an H100-like baseline at
equal total area.

(a) GPT-1.7B fully SRAM-resident: speedup vs available on-chip SRAM
    bandwidth (buffer_bw sweep), with and without MQA.
(b) GPT-175B decode with 3D-stacked DRAM: speedup + latency breakdown vs
    stacking-DRAM bandwidth (0.25-4 TB/s/100mm^2; H100 HBM ~ 0.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from benchmarks.common import save_artifact
from repro.core.baselines import gpu_cluster_eval
from repro.core.design_space import WSCDesign
from repro.core.evaluator import evaluate_design
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS, inference_workload


def _mqa(wl, on: bool):
    return dataclasses.replace(wl, n_kv=1) if on else wl


def run(quick: bool = False) -> Dict:
    out: Dict = {"sram_resident": [], "stacked_dram": []}

    # ---- (a) GPT-1.7B in SRAM ------------------------------------------
    # SRAM-dominated small cores (WSE2-style): capacity for weights+KV on
    # wafer; sweep the per-core SRAM bandwidth
    wl_d = inference_workload(GPT_BENCHMARKS[0], "decode", batch=32, seq=2048)
    for mqa in (False, True):
        wl = _mqa(wl_d, mqa)
        gpu_t, _ = gpu_cluster_eval(wl, mqa=mqa)
        for bw in ((512, 2048) if quick else (256, 512, 1024, 2048)):
            d = WSCDesign(dataflow="WS", mac_num=16, buffer_kb=1024,
                          buffer_bw=bw, noc_bw=512, core_array=(16, 16),
                          inter_reticle_bw_ratio=1.0, use_stacked_dram=False,
                          reticle_array=(8, 8), integration="infosow")
            v = validate(d)
            if not v.ok:
                continue
            r = evaluate_design(v.design, wl, max_strategies=8)
            if r.feasible:
                out["sram_resident"].append({
                    "mqa": mqa, "sram_bw_bits": bw,
                    "speedup": r.throughput / gpu_t})

    # ---- (b) GPT-175B decode with stacked DRAM --------------------------
    wl_d = inference_workload(GPT_BENCHMARKS[7], "decode", batch=32, seq=2048)
    for mqa in (False, True):
        wl = _mqa(wl_d, mqa)
        gpu_t, _ = gpu_cluster_eval(wl, mqa=mqa)
        for dbw in ((0.5, 4.0) if quick else (0.25, 0.5, 1.0, 2.0, 4.0)):
            d = WSCDesign(dataflow="WS", mac_num=512, buffer_kb=256,
                          buffer_bw=1024, noc_bw=512, core_array=(10, 10),
                          inter_reticle_bw_ratio=1.0, use_stacked_dram=True,
                          dram_bw_tbps_per_100mm2=dbw, reticle_array=(8, 8),
                          integration="infosow")
            v = validate(d)
            if not v.ok:
                continue
            r = evaluate_design(v.design, wl, max_strategies=8)
            if r.feasible:
                bd = r.step.breakdown
                out["stacked_dram"].append({
                    "mqa": mqa, "dram_bw": dbw,
                    "speedup": r.throughput / gpu_t,
                    "breakdown": bd})
    a_max = max((r["speedup"] for r in out["sram_resident"]), default=0)
    b_max = max((r["speedup"] for r in out["stacked_dram"]), default=0)
    out["max_sram_speedup"] = a_max
    out["max_dram_speedup"] = b_max
    save_artifact("fig11_inference", out)
    print("\n=== Fig.11: inference speedup vs H100-like (equal area) ===")
    print("(a) GPT-1.7B SRAM-resident:")
    for r in out["sram_resident"]:
        print(f"  mqa={r['mqa']!s:5s} sram_bw={r['sram_bw_bits']:5d}b "
              f"speedup={r['speedup']:.1f}x")
    print("(b) GPT-175B stacked-DRAM decode:")
    for r in out["stacked_dram"]:
        print(f"  mqa={r['mqa']!s:5s} dram_bw={r['dram_bw']:.2f}TB/s/100mm2 "
              f"speedup={r['speedup']:.1f}x")
    print(f"max speedups: SRAM {a_max:.1f}x, stacked-DRAM {b_max:.1f}x "
          f"(paper: up to 16.9x w/o MQA SRAM; 9.8x stacked)")
    return out


if __name__ == "__main__":
    run()
