"""Roofline table from the multi-pod dry-run artifacts (deliverable g).

Reads benchmarks/artifacts/dryrun/*.json (written by repro.launch.dryrun)
and emits the per-(arch x shape x mesh) three-term roofline table used in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from benchmarks.common import save_artifact

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def collect(variant: str = "baseline") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{variant}.json"))):
        d = json.load(open(path))
        row = {k: d.get(k) for k in ("arch", "shape", "mesh", "variant",
                                     "status")}
        if d.get("status") == "ok":
            r = d["roofline"]
            dom_t = max(r["compute_s"], r["memory_s"], r["collective_s"])
            row.update({
                "compute_s": r["compute_s"],
                "memory_s": r["memory_s"],
                "collective_s": r["collective_s"],
                "dominant": r["dominant"],
                "roofline_frac": r["compute_s"] / dom_t if dom_t else 0.0,
                "useful_ratio": r["useful_ratio"],
                "state_gb_per_chip": d.get("state_bytes_per_chip", 0) / 1e9,
                "temp_gb_per_chip": d.get("memory", {}).get(
                    "temp_size_in_bytes", 0) / 1e9,
                "compile_s": d.get("compile_s"),
            })
        rows.append(row)
    return rows


def run(quick: bool = False) -> Dict:
    rows = collect()
    ok = [r for r in rows if r["status"] == "ok"]
    out = {"rows": rows, "n_ok": len(ok),
           "n_skip": sum(1 for r in rows if r["status"].startswith("SKIP")),
           "n_fail": sum(1 for r in rows if r["status"] == "FAIL")}
    save_artifact("roofline_table", out)
    print("\n=== Roofline table (from multi-pod dry-run) ===")
    print(f"cells: {len(rows)}  ok: {out['n_ok']}  skip: {out['n_skip']}  "
          f"fail: {out['n_fail']}")
    print(f"{'arch':16s}{'shape':13s}{'mesh':12s}{'dom':11s}"
          f"{'comp_s':>9s}{'mem_s':>9s}{'coll_s':>9s}{'frac':>7s}{'useful':>8s}")
    for r in sorted(ok, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        print(f"{r['arch']:16s}{r['shape']:13s}{r['mesh']:12s}"
              f"{r['dominant']:11s}{r['compute_s']:9.3f}{r['memory_s']:9.3f}"
              f"{r['collective_s']:9.3f}{r['roofline_frac']:7.3f}"
              f"{min(r['useful_ratio'], 99.9):8.3f}")
    return out


if __name__ == "__main__":
    run()
