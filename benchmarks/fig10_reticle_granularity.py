"""Fig. 10 reproduction: reticle-granularity trade-off (Takeaway 3). For
several core granularities, sweep the core-array size up to the reticle
area limit; report training throughput vs reticle peak FLOPS, the optimal
per cluster, and the area fraction it occupies.
"""
from __future__ import annotations

from typing import Dict

from benchmarks.common import save_artifact
from repro.core import components as C
from repro.core.design_space import WSCDesign
from repro.core.evaluator import evaluate_design
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS


def run(quick: bool = False) -> Dict:
    wl = GPT_BENCHMARKS[1] if quick else GPT_BENCHMARKS[7]   # GPT-3.6B / 175B
    rows = []
    macs = (256, 512) if quick else (128, 256, 512, 1024, 2048)
    arrays = ((4, 4), (8, 8), (12, 12), (16, 16), (20, 20), (24, 24))
    for mac in macs:
        cluster = []
        for arr in arrays:
            d = WSCDesign(dataflow="WS", mac_num=mac, buffer_kb=128,
                          buffer_bw=1024, noc_bw=512, core_array=arr,
                          inter_reticle_bw_ratio=1.0, use_stacked_dram=True,
                          dram_bw_tbps_per_100mm2=1.0, reticle_array=(8, 8),
                          integration="infosow")
            v = validate(d)
            if not v.ok:
                continue
            r = evaluate_design(v.design, wl, max_strategies=8)
            if not r.feasible:
                continue
            cluster.append({
                "mac": mac, "core_array": list(arr),
                "reticle_tflops": v.design.reticle_flops() / 1e12,
                "area_frac": v.design.reticle_area_mm2() / C.RETICLE_AREA_MM2,
                "throughput": r.throughput,
            })
        if cluster:
            best = max(cluster, key=lambda x: x["throughput"])
            best = dict(best, optimal=True)
            rows.extend([c if c is not best else best for c in cluster])
    out = {"workload": wl.name, "rows": rows}
    opt = [r for r in rows if r.get("optimal")]
    if opt:
        gbest = max(opt, key=lambda r: r["throughput"])
        out["best"] = gbest
    save_artifact("fig10_reticle_granularity", out)
    print("\n=== Fig.10: reticle granularity ===")
    print(f"{'mac':>6s}{'array':>9s}{'ret TFLOPS':>12s}{'area%':>8s}"
          f"{'thpt tok/s':>13s}{'opt':>5s}")
    for r in rows:
        print(f"{r['mac']:6d}{str(tuple(r['core_array'])):>9s}"
              f"{r['reticle_tflops']:12.1f}{100*r['area_frac']:8.1f}"
              f"{r['throughput']:13.0f}{'*' if r.get('optimal') else '':>5s}")
    if opt:
        print(f"best reticle: {out['best']['reticle_tflops']:.0f} TFLOPS at "
              f"{100*out['best']['area_frac']:.0f}% of reticle area limit "
              f"(paper: optimum typically at 50-60%, not the limit)")
    return out


if __name__ == "__main__":
    run()
