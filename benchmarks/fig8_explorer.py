"""Fig. 8 reproduction: explorer efficiency — random search vs MOBO vs
MFMOBO (hypervolume vs iteration, averaged over seeds). f1 = analytical,
f0 = GNN-based evaluation, exactly as the paper runs its loop — but on the
batched evaluation backend: proposals are acquired as q-point batches
(greedy q-EHVI) and scored through `evaluate_design_batch`, with the
cross-call eval cache deduplicating repeat visits. Reports candidates/sec.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import save_artifact, trained_gnn
from repro.core.evaluator import batched_objectives, eval_cache_stats
from repro.core.mfmobo import run_mfmobo, run_mobo, run_random
from repro.core.workload import GPT_BENCHMARKS


def run(quick: bool = False) -> Dict:
    gnn, _ = trained_gnn(quick=quick)
    wl = GPT_BENCHMARKS[0]            # GPT-1.7B (paper also shows 175B/530B)
    f1 = batched_objectives(wl, "analytical")
    f0 = batched_objectives(wl, "gnn", gnn_params=gnn)
    seeds = (0,) if quick else (0, 1, 2)
    N0 = 8 if quick else 14
    N1 = 10 if quick else 18
    cand = 48 if quick else 96
    q = 2 if quick else 4
    curves = {"random": [], "mobo": [], "mfmobo": []}
    n_evals = 0
    stats0 = eval_cache_stats()        # delta vs other benchmarks' traffic
    t_all = time.time()
    for seed in seeds:
        t0 = time.time()
        tr_r = run_random(f0, N=N0, seed=seed)
        tr_m = run_mobo(f0, d0=3, N=N0, seed=seed, n_candidates=cand, q=q)
        tr_f = run_mfmobo(f0, f1, d0=2, d1=3, k=3, N0=N0, N1=N1, seed=seed,
                          n_candidates=cand, q=q)
        curves["random"].append(tr_r.hv)
        curves["mobo"].append(tr_m.hv)
        curves["mfmobo"].append(tr_f.hv)
        n_evals += tr_r.n_evals + tr_m.n_evals + tr_f.n_evals
        print(f"  seed {seed}: {time.time()-t0:.0f}s  "
              f"final hv random={tr_r.hv[-1]:.2f} mobo={tr_m.hv[-1]:.2f} "
              f"mfmobo={tr_f.hv[-1]:.2f}")
    wall_s = time.time() - t_all

    def avg(tag):
        n = min(len(c) for c in curves[tag])
        return np.mean([c[:n] for c in curves[tag]], axis=0).tolist()

    out = {k: avg(k) for k in curves}
    # convergence speed: iterations for mobo to reach mfmobo's mid hv
    tgt = out["mfmobo"][len(out["mfmobo"]) // 2]
    it_f = next((i for i, h in enumerate(out["mfmobo"]) if h >= tgt),
                len(out["mfmobo"]))
    it_m = next((i for i, h in enumerate(out["mobo"]) if h >= tgt),
                len(out["mobo"]))
    out["convergence_speedup_vs_mobo"] = (it_m + 1) / (it_f + 1)
    hv_gain = (out["mfmobo"][min(len(out["mobo"]), len(out["mfmobo"])) - 1]
               / max(out["mobo"][-1], 1e-9) - 1.0)
    out["hv_improvement_at_equal_iters"] = hv_gain
    out["q"] = q
    out["n_evaluations"] = n_evals
    out["wall_s"] = wall_s
    out["candidates_per_sec"] = n_evals / max(wall_s, 1e-9)
    stats1 = eval_cache_stats()
    out["eval_cache"] = {k: stats1[k] - stats0.get(k, 0)
                         for k in ("hits", "misses")}
    save_artifact("fig8_explorer", out)
    print("\n=== Fig.8: explorer efficiency (avg hypervolume) ===")
    for k in ("random", "mobo", "mfmobo"):
        print(f"{k:8s} " + " ".join(f"{h:7.2f}" for h in out[k]))
    print(f"MFMOBO convergence speedup vs MOBO: "
          f"{out['convergence_speedup_vs_mobo']:.2f}x; "
          f"HV improvement at equal iterations: {100*hv_gain:.0f}%")
    print(f"explorer throughput: {out['candidates_per_sec']:.2f} "
          f"evaluated candidates/sec (q={q}, {n_evals} evals in "
          f"{wall_s:.0f}s)")
    return out


if __name__ == "__main__":
    run()
