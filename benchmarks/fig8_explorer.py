"""Fig. 8 reproduction: explorer efficiency — random search vs MOBO vs
MFMOBO (hypervolume vs iteration, averaged over seeds), expressed as
declarative campaigns (repro.explore, DESIGN.md §9): each method/seed cell
is a `CampaignSpec` — workload, strategy, fidelity schedule, budget — run
through the `Campaign` runner. f1 = analytical, f0 = GNN-based evaluation,
exactly as the paper runs its loop, on the batched fidelity backends with
q-point greedy q-EHVI proposals and the cross-call eval cache. The MFMOBO
campaign declares `calibrate_on_handover`: simulator traces from the
current Pareto neighborhood fine-tune the pre-trained GNN checkpoint
before f0 spends the rest of the budget. Reports candidates/sec and
per-fidelity-stage cache hit-rates.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
import shutil
import tempfile
import time
from typing import Dict

import numpy as np

from benchmarks.common import save_artifact, trained_gnn
from repro.core.evaluator import evaluate_objectives_batch
from repro.core.mfmobo import hv_ref, obj_space, warm_optimizer_kernels
from repro.core.pareto import hypervolume_2d
from repro.core.workload import GPT_BENCHMARKS
from repro.explore import Campaign, CampaignSpec, FidelitySchedule


def method_specs(workload: str, seed: int, *, N0: int, N1: int, cand: int,
                 q: int, quick: bool) -> Dict[str, CampaignSpec]:
    """The three Fig. 8 method cells as campaign specs (same budgets and
    seeds as the pre-campaign hand-wired loops)."""
    gnn_f0 = FidelitySchedule(f1="analytical", f0="gnn", d1=3, d0=3, k=0)
    return {
        "random": CampaignSpec(
            name=f"fig8-random-s{seed}", workload=workload,
            scenario="train", strategy="random", fidelity=gnn_f0,
            n_evals_f0=N0, q=N0, seed=seed),   # q=N0: one batched GNN pass
        "mobo": CampaignSpec(
            name=f"fig8-mobo-s{seed}", workload=workload, scenario="train",
            strategy="mobo", fidelity=gnn_f0, n_evals_f0=N0, q=q,
            n_candidates=cand, seed=seed),
        "mfmobo": CampaignSpec(
            name=f"fig8-mfmobo-s{seed}", workload=workload,
            scenario="train", strategy="mfmobo",
            fidelity=FidelitySchedule(
                f1="analytical", f0="gnn", d1=3, d0=2, k=3,
                calibrate_on_handover=True,
                calibration={"n_designs": 3 if quick else 6,
                             "epochs": 5 if quick else 15}),
            n_evals_f0=N0, n_evals_f1=N1, q=q, n_candidates=cand,
            seed=seed),
    }


def fleet_probe(quick: bool, gnn_params) -> Dict:
    """Fleet-scale grid execution probe (DESIGN.md §11): the fig8
    method×seed grid run three ways —

        serial-cold  one fresh worker process per campaign, no shared
                     caches: what the grid costs when each campaign is an
                     independent cold job (the pre-fleet deployment shape);
        fleet-cold   `workers` persistent processes sharing the on-disk
                     eval cache and the XLA compilation cache;
        fleet-warm   the same fleet re-run against the now-populated
                     persistent caches (fresh checkpoints, so every
                     campaign genuinely re-evaluates) — measures the
                     cross-campaign eval-cache hit-rate.
    """
    from repro.explore.fleet import FleetSpec, run_fleet

    wl = GPT_BENCHMARKS[0]
    seeds = (0,) if quick else (0, 1, 2)
    N0 = 8 if quick else 14
    N1 = 10 if quick else 18
    cand = 48 if quick else 96
    q = 2 if quick else 4
    workers = 2 if quick else 4

    root = tempfile.mkdtemp(prefix="fig8fleet-")
    params_path = os.path.join(root, "gnn_params.pkl")
    with open(params_path, "wb") as f:
        pickle.dump(gnn_params, f)
    campaigns = []
    for seed in seeds:
        for spec in method_specs(wl.name, seed, N0=N0, N1=N1, cand=cand,
                                 q=q, quick=quick).values():
            fid = dataclasses.replace(spec.fidelity,
                                      params_path=params_path)
            campaigns.append(dataclasses.replace(
                spec, name="fleet-" + spec.name, fidelity=fid))
    try:
        # serial-cold baseline: a fresh spawned process per campaign,
        # nothing shared — every campaign pays imports + XLA compiles
        t0 = time.time()
        serial_evals = 0
        for i, c in enumerate(campaigns):
            r = run_fleet(FleetSpec(name=f"serial-{i}", campaigns=(c,),
                                    workers=1))
            if r.errors:
                raise RuntimeError(f"serial baseline failed: {r.errors}")
            serial_evals += r.n_evals
        serial_wall = time.time() - t0

        fs = FleetSpec(
            name="fig8-fleet", campaigns=tuple(campaigns), workers=workers,
            host_devices=2,          # shard eval batches across 2 XLA lanes
            cache_dir=os.path.join(root, "evalcache"),
            compile_cache_dir=os.path.join(root, "xlacache"),
            checkpoint_dir=os.path.join(root, "ck"), checkpoint_every=2)
        cold = run_fleet(fs)
        if cold.errors:
            raise RuntimeError(f"fleet run failed: {cold.errors}")
        # fresh checkpoint dir: same campaigns recompute their evaluations
        # against the persistent eval cache the cold pass populated
        warm = run_fleet(dataclasses.replace(
            fs, name="fig8-fleet-warm",
            checkpoint_dir=os.path.join(root, "ck-warm")))
        if warm.errors:
            raise RuntimeError(f"warm fleet run failed: {warm.errors}")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    f0 = {"hits": 0, "misses": 0}
    for c in warm.campaigns:
        sc = (c or {}).get("stage_cache", {}).get("f0", {})
        f0["hits"] += sc.get("hits", 0)
        f0["misses"] += sc.get("misses", 0)
    warm_hit = f0["hits"] / max(f0["hits"] + f0["misses"], 1)
    # per-lane evaluator utilization, aggregated over every worker's
    # campaigns (each worker reports its process-local lane counters)
    lanes = {"n_lanes": 0, "sharded_calls": 0, "rows_sharded": 0,
             "jit_calls": 0, "rows_jit": 0}
    for c in list(cold.campaigns) + list(warm.campaigns):
        el = (c or {}).get("eval_lanes") or {}
        lanes["n_lanes"] = max(lanes["n_lanes"], el.get("n_lanes", 0))
        for k in ("sharded_calls", "rows_sharded", "jit_calls", "rows_jit"):
            lanes[k] += el.get(k, 0)
    return {
        "workers": workers,
        "host_devices": 2,
        "eval_lanes": lanes,
        "n_campaigns": len(campaigns),
        "n_evals": cold.n_evals,
        "serial_cold_wall_s": serial_wall,
        "fleet_wall_s": cold.wall_s,
        "fleet_warm_wall_s": warm.wall_s,
        "fleet_speedup": serial_wall / max(cold.wall_s, 1e-9),
        "fleet_warm_speedup": serial_wall / max(warm.wall_s, 1e-9),
        "fleet_candidates_per_sec": cold.fleet_candidates_per_sec,
        "fleet_warm_candidates_per_sec": warm.fleet_candidates_per_sec,
        "warm_f0_hit_rate": warm_hit,
        "crashes": cold.crashes + warm.crashes,
    }


def run(quick: bool = False) -> Dict:
    gnn, _ = trained_gnn(quick=quick)
    wl = GPT_BENCHMARKS[0]            # GPT-1.7B (paper also shows 175B/530B)
    seeds = (0,) if quick else (0, 1, 2)
    N0 = 8 if quick else 14
    N1 = 10 if quick else 18
    cand = 48 if quick else 96
    q = 2 if quick else 4
    curves = {"random": [], "mobo": [], "mfmobo": []}
    sim_hv = {"random": [], "mobo": [], "mfmobo": []}
    n_evals = 0
    calib_records = []
    stage_cache = {"f0": {"hits": 0, "misses": 0, "entries_added": 0},
                   "f1": {"hits": 0, "misses": 0, "entries_added": 0}}
    # compile the jitted optimizer programs (GP pair fit, scanned q-EHVI
    # acquire) for every pow2 capacity bucket the campaigns will touch, so
    # the timed wall below measures proposal throughput, not XLA compiles
    t0 = time.time()
    n_buckets = warm_optimizer_kernels(max(N0, N1), n_candidates=cand, q=q,
                                       workload=wl,
                                       n_designs_max=max(N0, N1))
    print(f"  optimizer+evaluator warmup: {n_buckets} shape buckets "
          f"compiled in {time.time()-t0:.1f}s")
    t_all = time.time()

    def hv_under_sim(trace):
        """Ground-truth final hypervolume: re-score every design the method
        evaluated with the (batched) simulator. mfmobo's own hv curve is
        measured by a GNN that calibration changes mid-run, so cross-method
        comparisons need one common instrument."""
        ys = evaluate_objectives_batch(trace.designs, wl, "sim")
        return hypervolume_2d(obj_space(ys), hv_ref(15000.0))

    for seed in seeds:
        t0 = time.time()
        specs = method_specs(wl.name, seed, N0=N0, N1=N1, cand=cand, q=q,
                             quick=quick)
        results = {m: Campaign(spec, gnn_params=gnn).run()
                   for m, spec in specs.items()}
        for m, r in results.items():
            curves[m].append(r.trace.hv)
            sim_hv[m].append(hv_under_sim(r.trace))
            n_evals += r.n_evals
            for stage, sc in r.stage_cache.items():
                for k in ("hits", "misses", "entries_added"):
                    stage_cache[stage][k] += sc.get(k, 0)
        for rec in results["mfmobo"].calibration:
            calib_records.append(dict(rec, seed=seed))
        print(f"  seed {seed}: {time.time()-t0:.0f}s  final hv "
              + " ".join(f"{m}={r.trace.hv[-1]:.2f}"
                         for m, r in results.items()))
    wall_s = time.time() - t_all

    def avg(tag):
        n = min(len(c) for c in curves[tag])
        return np.mean([c[:n] for c in curves[tag]], axis=0).tolist()

    out = {k: avg(k) for k in curves}
    # convergence speed: iterations for mobo to reach mfmobo's mid hv
    tgt = out["mfmobo"][len(out["mfmobo"]) // 2]
    it_f = next((i for i, h in enumerate(out["mfmobo"]) if h >= tgt),
                len(out["mfmobo"]))
    it_m = next((i for i, h in enumerate(out["mobo"]) if h >= tgt),
                len(out["mobo"]))
    out["convergence_speedup_vs_mobo"] = (it_m + 1) / (it_f + 1)
    hv_gain = (out["mfmobo"][min(len(out["mobo"]), len(out["mfmobo"])) - 1]
               / max(out["mobo"][-1], 1e-9) - 1.0)
    out["hv_improvement_at_equal_iters"] = hv_gain
    out["q"] = q
    out["n_evaluations"] = n_evals
    out["calibration"] = calib_records
    out["hv_sim_final"] = {k: float(np.mean(v)) for k, v in sim_hv.items()}
    out["wall_s"] = wall_s
    out["candidates_per_sec"] = n_evals / max(wall_s, 1e-9)
    out["eval_cache"] = {
        k: stage_cache["f0"][k] + stage_cache["f1"][k]
        for k in ("hits", "misses")}
    out["stage_cache"] = {
        stage: dict(sc, hit_rate=sc["hits"] / max(sc["hits"] + sc["misses"],
                                                  1))
        for stage, sc in stage_cache.items()}
    out["campaigns"] = sorted(s.name for s in method_specs(
        wl.name, seeds[0], N0=N0, N1=N1, cand=cand, q=q,
        quick=quick).values())
    print("\n  fleet probe: serial-cold vs shared-cache workers "
          "(repro.explore.fleet)...")
    out["fleet"] = fleet_probe(quick, gnn)
    save_artifact("fig8_explorer", out)
    print("\n=== Fig.8: explorer efficiency (avg hypervolume) ===")
    for k in ("random", "mobo", "mfmobo"):
        print(f"{k:8s} " + " ".join(f"{h:7.2f}" for h in out[k]))
    print(f"MFMOBO convergence speedup vs MOBO: "
          f"{out['convergence_speedup_vs_mobo']:.2f}x; "
          f"HV improvement at equal iterations: {100*hv_gain:.0f}%")
    print("final hv re-scored under sim (common instrument): "
          + "  ".join(f"{k}={v:.2f}" for k, v in out["hv_sim_final"].items()))
    print(f"explorer throughput: {out['candidates_per_sec']:.2f} "
          f"evaluated candidates/sec (q={q}, {n_evals} evals in "
          f"{wall_s:.0f}s)")
    for stage, sc in out["stage_cache"].items():
        print(f"eval cache [{stage}]: {sc['hits']}/{sc['hits']+sc['misses']}"
              f" hits ({100*sc['hit_rate']:.0f}%)")
    fl = out["fleet"]
    print(f"fleet [{fl['workers']} workers, {fl['n_campaigns']} campaigns]: "
          f"serial-cold {fl['serial_cold_wall_s']:.0f}s -> fleet "
          f"{fl['fleet_wall_s']:.0f}s ({fl['fleet_speedup']:.1f}x, "
          f"{fl['fleet_candidates_per_sec']:.2f} candidates/sec); warm "
          f"re-run {fl['fleet_warm_wall_s']:.0f}s "
          f"({fl['fleet_warm_speedup']:.1f}x) with "
          f"{100*fl['warm_f0_hit_rate']:.0f}% f0 cache hits")
    return out


if __name__ == "__main__":
    run()
