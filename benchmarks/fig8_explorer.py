"""Fig. 8 reproduction: explorer efficiency — random search vs MOBO vs
MFMOBO (hypervolume vs iteration, averaged over seeds). f1 = analytical,
f0 = GNN-based evaluation, exactly as the paper runs its loop — but on the
batched fidelity backends: proposals are acquired as q-point batches
(greedy q-EHVI) and scored through `evaluate_design_batch`, with the
cross-call eval cache deduplicating repeat visits. The MFMOBO run
additionally calibrates the GNN online at the f1 -> f0 handover
(calibration.GNNCalibrator): simulator traces from the current Pareto
neighborhood fine-tune the pre-trained checkpoint before f0 spends the
rest of the budget. Reports candidates/sec.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import save_artifact, trained_gnn
from repro.core.calibration import GNNCalibrator
from repro.core.evaluator import (batched_objectives, eval_cache_stats,
                                  evaluate_objectives_batch)
from repro.core.mfmobo import hv_ref, obj_space, run_mfmobo, run_mobo, run_random
from repro.core.pareto import hypervolume_2d
from repro.core.workload import GPT_BENCHMARKS


def run(quick: bool = False) -> Dict:
    gnn, _ = trained_gnn(quick=quick)
    wl = GPT_BENCHMARKS[0]            # GPT-1.7B (paper also shows 175B/530B)
    f1 = batched_objectives(wl, "analytical")
    f0 = batched_objectives(wl, "gnn", gnn_params=gnn)
    seeds = (0,) if quick else (0, 1, 2)
    N0 = 8 if quick else 14
    N1 = 10 if quick else 18
    cand = 48 if quick else 96
    q = 2 if quick else 4
    curves = {"random": [], "mobo": [], "mfmobo": []}
    sim_hv = {"random": [], "mobo": [], "mfmobo": []}
    n_evals = 0
    calib_records = []
    stats0 = eval_cache_stats()        # delta vs other benchmarks' traffic
    t_all = time.time()

    def hv_under_sim(trace):
        """Ground-truth final hypervolume: re-score every design the method
        evaluated with the (batched) simulator. mfmobo's own hv curve is
        measured by a GNN that calibration changes mid-run, so cross-method
        comparisons need one common instrument."""
        ys = evaluate_objectives_batch(trace.designs, wl, "sim")
        return hypervolume_2d(obj_space(ys), hv_ref(15000.0))
    for seed in seeds:
        t0 = time.time()
        tr_r = run_random(f0, N=N0, seed=seed)
        tr_m = run_mobo(f0, d0=3, N=N0, seed=seed, n_candidates=cand, q=q)
        cal = GNNCalibrator(gnn, wl, n_designs=3 if quick else 6,
                            epochs=5 if quick else 15, seed=seed)
        tr_f = run_mfmobo(cal.objectives(), f1, d0=2, d1=3, k=3, N0=N0,
                          N1=N1, seed=seed, n_candidates=cand, q=q,
                          on_handover=cal.on_handover)
        curves["random"].append(tr_r.hv)
        curves["mobo"].append(tr_m.hv)
        curves["mfmobo"].append(tr_f.hv)
        sim_hv["random"].append(hv_under_sim(tr_r))
        sim_hv["mobo"].append(hv_under_sim(tr_m))
        sim_hv["mfmobo"].append(hv_under_sim(tr_f))
        n_evals += tr_r.n_evals + tr_m.n_evals + tr_f.n_evals
        for rec in cal.records:
            calib_records.append({
                "seed": seed, "n_designs": rec.n_designs,
                "n_graphs": rec.n_graphs, "train_s": rec.train_s,
                "val_kendall_tau": rec.history.best_val_kendall_tau})
        print(f"  seed {seed}: {time.time()-t0:.0f}s  "
              f"final hv random={tr_r.hv[-1]:.2f} mobo={tr_m.hv[-1]:.2f} "
              f"mfmobo={tr_f.hv[-1]:.2f}")
    wall_s = time.time() - t_all

    def avg(tag):
        n = min(len(c) for c in curves[tag])
        return np.mean([c[:n] for c in curves[tag]], axis=0).tolist()

    out = {k: avg(k) for k in curves}
    # convergence speed: iterations for mobo to reach mfmobo's mid hv
    tgt = out["mfmobo"][len(out["mfmobo"]) // 2]
    it_f = next((i for i, h in enumerate(out["mfmobo"]) if h >= tgt),
                len(out["mfmobo"]))
    it_m = next((i for i, h in enumerate(out["mobo"]) if h >= tgt),
                len(out["mobo"]))
    out["convergence_speedup_vs_mobo"] = (it_m + 1) / (it_f + 1)
    hv_gain = (out["mfmobo"][min(len(out["mobo"]), len(out["mfmobo"])) - 1]
               / max(out["mobo"][-1], 1e-9) - 1.0)
    out["hv_improvement_at_equal_iters"] = hv_gain
    out["q"] = q
    out["n_evaluations"] = n_evals
    out["calibration"] = calib_records
    out["hv_sim_final"] = {k: float(np.mean(v)) for k, v in sim_hv.items()}
    out["wall_s"] = wall_s
    out["candidates_per_sec"] = n_evals / max(wall_s, 1e-9)
    stats1 = eval_cache_stats()
    out["eval_cache"] = {k: stats1[k] - stats0.get(k, 0)
                         for k in ("hits", "misses")}
    save_artifact("fig8_explorer", out)
    print("\n=== Fig.8: explorer efficiency (avg hypervolume) ===")
    for k in ("random", "mobo", "mfmobo"):
        print(f"{k:8s} " + " ".join(f"{h:7.2f}" for h in out[k]))
    print(f"MFMOBO convergence speedup vs MOBO: "
          f"{out['convergence_speedup_vs_mobo']:.2f}x; "
          f"HV improvement at equal iterations: {100*hv_gain:.0f}%")
    print("final hv re-scored under sim (common instrument): "
          + "  ".join(f"{k}={v:.2f}" for k, v in out["hv_sim_final"].items()))
    print(f"explorer throughput: {out['candidates_per_sec']:.2f} "
          f"evaluated candidates/sec (q={q}, {n_evals} evals in "
          f"{wall_s:.0f}s)")
    return out


if __name__ == "__main__":
    run()
