"""Fig. 7 reproduction: evaluation-model speedup + accuracy vs the
cycle-approximate simulator (our CA-sim stand-in, DESIGN.md §3).

Chunk latencies are dispatched through the fidelity backend registry
(repro.core.fidelity), so this benchmark exercises exactly the estimators
the explorer uses. For a set of (design, workload) chunk compilations:
  (a) wall-time of sim / analytical / GNN chunk evaluation (scalar
      reference paths) plus the batched design-level path per fidelity,
  (b) latency error of analytical + GNN vs sim,
  (c) Kendall's tau rank correlation vs sim across designs.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from benchmarks.common import (
    kendall_tau,
    sample_valid_designs,
    save_artifact,
    trained_gnn,
)
from repro.core.compiler import compile_chunk
from repro.core.evaluator import clear_eval_cache, evaluate_design_batch
from repro.core.fidelity import get_backend
from repro.core.workload import GPT_BENCHMARKS


def run(quick: bool = False) -> Dict:
    gnn, info = trained_gnn(quick=quick)
    n_eval = 6 if quick else 12
    designs = sample_valid_designs(n_eval, seed=7)
    bench = GPT_BENCHMARKS[:2] if quick else GPT_BENCHMARKS[:4]
    backends = {name: get_backend(name)
                for name in ("sim", "analytical", "gnn")}
    rows = []
    for wl in bench:
        sims, anas, gnns = [], [], []
        t_sim = t_ana = t_gnn = 0.0
        for d in designs:
            g = compile_chunk(d, wl, tp=16, mb_tokens=2048,
                              cores_per_chunk=64)
            t0 = time.time()
            s = backends["sim"].chunk_latency(g, d)
            t_sim += time.time() - t0
            t0 = time.time()
            a = backends["analytical"].chunk_latency(g, d)
            t_ana += time.time() - t0
            t0 = time.time()
            gn = backends["gnn"].chunk_latency(g, d, gnn)
            t_gnn += time.time() - t0
            sims.append(s); anas.append(a); gnns.append(gn)
        sims, anas, gnns = map(np.array, (sims, anas, gnns))
        rows.append({
            "workload": wl.name,
            "speedup_analytical": t_sim / max(t_ana, 1e-9),
            "speedup_gnn": t_sim / max(t_gnn, 1e-9),
            "err_analytical_pct": float(np.mean(np.abs(anas - sims) / sims) * 100),
            "err_gnn_pct": float(np.mean(np.abs(gnns - sims) / sims) * 100),
            "kt_analytical": kendall_tau(anas, sims),
            "kt_gnn": kendall_tau(gnns, sims),
        })

    # batched design-level throughput per fidelity on the first workload
    wl = bench[0]
    batched_cps = {}
    for name in ("analytical", "gnn", "sim"):
        kw = {"gnn_params": gnn} if name == "gnn" else {}
        clear_eval_cache()
        t0 = time.time()
        evaluate_design_batch(designs, wl, fidelity=name,
                              max_strategies=8, **kw)
        batched_cps[name] = len(designs) / max(time.time() - t0, 1e-9)

    out = {"gnn_training": info, "rows": rows,
           "batched_candidates_per_sec": batched_cps}
    save_artifact("fig7_eval_models", out)
    print(f"\n=== Fig.7: evaluation models vs CA-sim ===")
    print(f"{'workload':12s}{'spd(ana)':>10s}{'spd(gnn)':>10s}"
          f"{'err(ana)%':>11s}{'err(gnn)%':>11s}{'KT(ana)':>9s}{'KT(gnn)':>9s}")
    for r in rows:
        print(f"{r['workload']:12s}{r['speedup_analytical']:10.1f}"
              f"{r['speedup_gnn']:10.1f}{r['err_analytical_pct']:11.2f}"
              f"{r['err_gnn_pct']:11.2f}{r['kt_analytical']:9.2f}"
              f"{r['kt_gnn']:9.2f}")
    print("batched design-level candidates/sec: "
          + "  ".join(f"{k}={v:.1f}" for k, v in batched_cps.items()))
    return out


if __name__ == "__main__":
    run()
