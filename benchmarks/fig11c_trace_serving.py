"""Fig. 11(c) extension: trace-driven multi-tenant serving for GPT-175B.

Fig. 11(b) scores a stationary arrival batch under one global SLO. This
benchmark runs the trace-driven subsystem (repro.core.traces, DESIGN.md
§14) on the scenario the ROADMAP names: interactive chat sharing a wafer
with offline batch traffic through a Markov-modulated load spike —

  (1) a policy ablation on a probe design pool: every design scored under
      FIFO, strict-priority, preempt-batch-for-interactive and
      prefill/decode-disaggregated routing on the *same* spike trace —
      same design = equal power, so the worst-window interactive goodput
      deltas are pure scheduling-policy effects;
  (2) the spike-trace goodput/power front: (worst-window interactive
      goodput, power) Pareto front of the probe pool under the best
      policy per design;
  (3) a "trace_serving" campaign with the policy axis searched
      (`TraceSpec.policy="search"`): MOBO proposes (design, policy)
      points jointly and the front records which policies win.

The chat tenant's SLO is calibrated from the probe pool's FIFO medians so
it binds during the spike; the batch tenant is offline (preemptible, slack
SLO). Artifacts land in benchmarks/artifacts/fig11c_trace_serving.json;
the `trace_serving` record in BENCH_dse.json is floored in bench-smoke
(worst-window goodput > 0 and some non-FIFO policy beating FIFO).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import sample_valid_designs, save_artifact
from repro.core.pareto import pareto_front, to_max_space
from repro.core.traces import (
    PolicyDesign,
    TenantClass,
    evaluate_trace_serving_batch,
    spike_trace,
)
from repro.core.workload import GPT_BENCHMARKS
from repro.explore import Campaign, CampaignSpec, FidelitySchedule, TraceSpec

POLICIES = ("fifo", "priority", "preempt", "disaggregated")


def make_trace(n_requests: int, chat_slo=(5.0, 0.5), seed: int = 17):
    """The benchmark's workload: 50/50 interactive chat + offline batch,
    bursty Markov-modulated arrivals (8x spikes)."""
    tenants = (
        TenantClass("chat", ttft_s=chat_slo[0], tpot_s=chat_slo[1],
                    priority=2, interactive=True),
        TenantClass("batch", ttft_s=1e4, tpot_s=1e3,
                    priority=0, interactive=False),
    )
    return spike_trace(
        n_requests, rate=0.35, spike_factor=8.0, spike_len=24, gap_len=64,
        tenants=tenants, shares=(0.5, 0.5),
        prompt_ranges=((256, 1024), (256, 1024)),
        out_ranges=((16, 48), (64, 160)), seed=seed)


def tenant_dicts(trace) -> List[Dict]:
    return [
        {"name": "chat", "ttft_s": trace.tenants[0].ttft_s,
         "tpot_s": trace.tenants[0].tpot_s, "priority": 2,
         "interactive": True, "share": 0.5,
         "prompt_range": (256, 1024), "out_range": (16, 48)},
        {"name": "batch", "ttft_s": 1e4, "tpot_s": 1e3, "priority": 0,
         "interactive": False, "share": 0.5,
         "prompt_range": (256, 1024), "out_range": (64, 160)},
    ]


def explorer_spec(workload: str, trace, slots: int, window_steps: int,
                  quick: bool, seed: int) -> CampaignSpec:
    """The searched-policy campaign: candidates are (design, policy)
    points, objectives (worst-window interactive goodput, power/wafer)."""
    return CampaignSpec(
        name="fig11c-trace-serving", workload=workload,
        scenario="trace_serving", strategy="mobo",
        fidelity=FidelitySchedule(f0="analytical", d0=4, k=0),
        n_evals_f0=8 if quick else 20, q=4, seed=7,
        max_strategies=8,
        trace=TraceSpec(
            kind="spike", n_requests=trace.n_requests, rate=0.35,
            seed=seed, slots=slots, window_steps=window_steps,
            policy="search", spike_factor=8.0, spike_len=24, gap_len=64,
            tenants=tuple(tenant_dicts(trace))))


def run(quick: bool = False) -> Dict:
    wl = GPT_BENCHMARKS[7]                          # GPT-175B
    n_req = 48 if quick else 128
    slots = 8
    window_steps = 32
    trace_seed = 17

    # ---- SLO calibration: FIFO medians on the probe pool ---------------
    probe_trace = make_trace(n_req, chat_slo=(1e9, 1e9), seed=trace_seed)
    designs = sample_valid_designs(12 if quick else 48, seed=23)
    probe = evaluate_trace_serving_batch(
        designs, wl, probe_trace, slots=slots, policy="fifo",
        window_steps=window_steps, max_strategies=8)
    feas = [r for r in probe if r.feasible]
    if not feas:
        raise RuntimeError("no feasible design in the trace-serving probe")
    # bind at the FIFO medians: during a spike FIFO queues chat behind
    # batch, so the median-calibrated bound fails exactly where a
    # priority/preempt/disaggregated policy can rescue it
    chat_slo = (float(np.median([r.ttft_s for r in feas])),
                float(np.median([r.tpot_s for r in feas])))
    trace = make_trace(n_req, chat_slo=chat_slo, seed=trace_seed)

    # ---- (1) policy ablation at equal power ----------------------------
    pool = [d for d, r in zip(designs, probe) if r.feasible]
    by_policy = {
        pol: evaluate_trace_serving_batch(
            pool, wl, trace, slots=slots, policy=pol,
            window_steps=window_steps, max_strategies=8)
        for pol in POLICIES
    }
    ablation = []
    n_beats = 0
    for i in range(len(pool)):
        row = {"design": i}
        for pol in POLICIES:
            r = by_policy[pol][i]
            row[pol] = {
                "worst_window_goodput_tok_s": r.worst_window_goodput_tok_s,
                "interactive_goodput_tok_s": r.interactive_goodput_tok_s,
                "goodput_tok_s": r.goodput_tok_s,
                "power_w": r.power_w,
                "n_preemptions": r.n_preemptions,
                "chat_slo_attainment":
                    r.per_tenant.get("chat", {}).get("slo_attainment", 0.0),
            }
        best_alt = max(row[p]["worst_window_goodput_tok_s"]
                       for p in POLICIES if p != "fifo")
        row["best_alt_policy"] = max(
            (p for p in POLICIES if p != "fifo"),
            key=lambda p: row[p]["worst_window_goodput_tok_s"])
        row["beats_fifo"] = bool(
            best_alt > row["fifo"]["worst_window_goodput_tok_s"])
        n_beats += row["beats_fifo"]
        ablation.append(row)
    policy_beats_fifo = n_beats > 0

    # ---- (2) spike-trace goodput/power front ---------------------------
    pts = []
    for i in range(len(pool)):
        best_pol = max(POLICIES, key=lambda p: by_policy[p][i]
                       .worst_window_goodput_tok_s)
        r = by_policy[best_pol][i]
        if r.worst_window_goodput_tok_s > 0:
            pts.append((r.worst_window_goodput_tok_s,
                        max(r.power_w, 1.0), best_pol))
    front = []
    if pts:
        fp = pareto_front(to_max_space([p[0] for p in pts],
                                       [p[1] for p in pts]))
        by_key = {(g, -pw): pol for g, pw, pol in pts}
        front = [{"worst_window_goodput_tok_s": float(g),
                  "power_w": float(-p),
                  "policy": by_key.get((g, p), "?")}
                 for g, p in fp]

    # ---- (3) searched-policy campaign ----------------------------------
    spec = explorer_spec(wl.name, trace, slots, window_steps, quick,
                         trace_seed)
    res = Campaign(spec).run()
    tr = res.trace
    camp_best = max((y[0] for y in tr.ys), default=0.0)
    front_policies = sorted({f["design"].get("policy", "?")
                             for f in res.front})
    # acceptance: the campaign's best searched point, re-scored under every
    # policy on ITS design (same design = equal power) — some non-FIFO
    # policy must beat FIFO on worst-window interactive goodput
    camp_beats_fifo = False
    camp_ablation = {}
    if res.front:
        best = max(res.front, key=lambda f: f[spec.objectives[0].name])
        bd = best["design"]
        from repro.core.design_space import WSCDesign
        d = WSCDesign(**{k: tuple(v) if isinstance(v, list) else v
                         for k, v in bd["design"].items()})
        rs = evaluate_trace_serving_batch(
            [PolicyDesign(d, p) for p in POLICIES], wl, trace,
            slots=slots, window_steps=window_steps, max_strategies=8)
        camp_ablation = {r.policy: {
            "worst_window_goodput_tok_s": r.worst_window_goodput_tok_s,
            "power_w": r.power_w} for r in rs}
        camp_beats_fifo = any(
            r.policy != "fifo" and r.worst_window_goodput_tok_s
            > camp_ablation["fifo"]["worst_window_goodput_tok_s"]
            for r in rs)

    worst_best = max((row[p]["worst_window_goodput_tok_s"]
                      for row in ablation for p in POLICIES), default=0.0)
    out = {
        "workload": wl.name,
        "trace": {"kind": "spike", "n_requests": n_req, "rate": 0.35,
                  "spike_factor": 8.0, "slots": slots,
                  "window_steps": window_steps, "seed": trace_seed,
                  "tenants": ["chat(interactive,prio=2)",
                              "batch(offline,prio=0)"]},
        "chat_slo": {"ttft_s": chat_slo[0], "tpot_s": chat_slo[1]},
        "ablation": ablation,
        "trace_front": front,
        "trace_serving": {
            "n_designs": len(pool),
            "n_policy_beats_fifo": n_beats,
            "policy_beats_fifo": bool(policy_beats_fifo or camp_beats_fifo),
            "worst_window_goodput_best": float(worst_best),
            "campaign_goodput_best": float(camp_best),
            "campaign_beats_fifo": bool(camp_beats_fifo),
            "campaign_front_policies": front_policies,
            "campaign_ablation": camp_ablation,
        },
        "explorer": {"n_evals": tr.n_evals,
                     "hv_final": tr.hv[-1] if tr.hv else 0.0,
                     "campaign": spec.name,
                     "candidates_per_sec": res.candidates_per_sec,
                     "wall_s": res.wall_s,
                     "front_size": len(res.front)},
        "stage_cache": res.stage_cache,
    }
    save_artifact("fig11c_trace_serving", out)

    print("\n=== Fig.11c: trace-driven multi-tenant serving (GPT-175B) ===")
    print(f"trace: {n_req} req spike (8x bursts), chat+batch 50/50, "
          f"{slots} slots; chat SLO ttft<={chat_slo[0]:.3f}s "
          f"tpot<={chat_slo[1]:.4f}s")
    print(f"ablation: {n_beats}/{len(pool)} designs where a non-FIFO "
          f"policy beats FIFO on worst-window chat goodput")
    for p in front[:6]:
        print(f"  front: worst-window goodput="
              f"{p['worst_window_goodput_tok_s']:9.1f} tok/s  "
              f"power={p['power_w']:9.0f} W  [{p['policy']}]")
    print(f"campaign: {tr.n_evals} searched (design, policy) evals, best "
          f"worst-window goodput {camp_best:.1f} tok/s, front policies "
          f"{front_policies}")
    if camp_ablation:
        for pol, m in camp_ablation.items():
            print(f"  best-design ablation {pol:14s}: "
                  f"worst-window={m['worst_window_goodput_tok_s']:9.1f} "
                  f"tok/s power={m['power_w']:8.0f} W")
    return out


if __name__ == "__main__":
    run()
