"""Fig. 9 reproduction: core-granularity trade-off. Sweep core computational
power (FLOPS = 2 x mac_num x 1 GHz), optimize the remaining knobs by random
search per bucket, report best training throughput + EDP, for both
integration styles (Takeaways 1-2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from benchmarks.common import save_artifact
from repro.core.design_space import WSCDesign
from repro.core.evaluator import evaluate_design
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS

MACS = (32, 128, 256, 512, 1024, 2048, 4096)


def run(quick: bool = False) -> Dict:
    rng = np.random.default_rng(0)
    wl = GPT_BENCHMARKS[1] if quick else GPT_BENCHMARKS[7]   # 3.6B / 175B
    n_samples = 4 if quick else 10
    rows = []
    for integration in ("infosow", "die_stitching"):
        for mac in (MACS[1::2] if quick else MACS):
            best = None
            for _ in range(n_samples):
                # buffer bandwidth must feed the MAC array (weight-stationary
                # streaming needs ~pe_cols operands/cycle), so it co-scales
                # with core size — this is what makes very large cores pay
                # the SRAM-port area penalty (paper: module efficiency)
                feed_bw = int(min(4096, max(512, mac)))
                d = WSCDesign(
                    dataflow="WS",
                    mac_num=mac,
                    buffer_kb=int(rng.choice([64, 128, 256, 512])),
                    buffer_bw=feed_bw,
                    noc_bw=int(rng.choice([256, 512, 1024])),
                    core_array=tuple(rng.choice([6, 8, 10, 12], 2)),
                    inter_reticle_bw_ratio=float(rng.choice([0.5, 1.0])),
                    use_stacked_dram=True,
                    dram_bw_tbps_per_100mm2=float(rng.choice([0.5, 1.0, 2.0])),
                    reticle_array=tuple(rng.choice([6, 8, 10], 2)),
                    integration=integration,
                )
                v = validate(d)
                if not v.ok:
                    continue
                r = evaluate_design(v.design, wl, max_strategies=8)
                if not r.feasible:
                    continue
                edp = (1.0 / r.throughput) ** 2 * r.power_w  # per-token EDP
                cand = {"mac": mac, "core_gflops": 2 * mac,
                        "throughput": r.throughput, "power_w": r.power_w,
                        "edp": edp, "integration": integration,
                        "design": v.design.describe()}
                if best is None or cand["throughput"] > best["throughput"]:
                    best = cand
            if best:
                rows.append(best)
    out = {"workload": wl.name, "rows": rows}
    # optimal band (Takeaway 1: 512G-1T FLOPS cores)
    by_t = sorted((r for r in rows if r["integration"] == "infosow"),
                  key=lambda r: -r["throughput"])
    out["optimal_core_gflops"] = by_t[0]["core_gflops"] if by_t else None
    save_artifact("fig9_core_granularity", out)
    print("\n=== Fig.9: core granularity (throughput/EDP vs core FLOPS) ===")
    print(f"{'integr':14s}{'coreGF':>8s}{'thpt tok/s':>13s}{'power kW':>10s}{'EDP':>12s}")
    for r in rows:
        print(f"{r['integration']:14s}{r['core_gflops']:8d}"
              f"{r['throughput']:13.0f}{r['power_w']/1e3:10.1f}{r['edp']:12.3e}")
    print(f"optimal core granularity: {out['optimal_core_gflops']} GFLOPS "
          f"(paper band: 512-1000 GFLOPS)")
    return out


if __name__ == "__main__":
    run()
