"""Fig. 12 reproduction: GPT-175B inference speedup with heterogeneous
prefill/decode designs at core / reticle / wafer granularity (Takeaway 5).
"""
from __future__ import annotations

from typing import Dict

from benchmarks.common import save_artifact
from repro.core.baselines import gpu_cluster_eval
from repro.core.design_space import WSCDesign
from repro.core.heterogeneity import evaluate_hetero
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS, inference_workload


def run(quick: bool = False) -> Dict:
    wl = inference_workload(GPT_BENCHMARKS[7], "decode", batch=32, seq=2048)
    gpu_t, _ = gpu_cluster_eval(wl)

    # prefill-tuned: low DRAM bw, more compute; decode-tuned: max DRAM bw
    d_prefill = validate(WSCDesign(
        dataflow="WS", mac_num=1024, buffer_kb=256, buffer_bw=1024,
        noc_bw=512, core_array=(10, 10), inter_reticle_bw_ratio=1.0,
        use_stacked_dram=True, dram_bw_tbps_per_100mm2=0.5,
        reticle_array=(8, 8), integration="infosow")).design
    d_decode = validate(WSCDesign(
        dataflow="WS", mac_num=256, buffer_kb=128, buffer_bw=1024,
        noc_bw=512, core_array=(9, 9), inter_reticle_bw_ratio=1.0,
        use_stacked_dram=True, dram_bw_tbps_per_100mm2=2.0,
        reticle_array=(8, 8), integration="infosow")).design
    assert d_prefill and d_decode

    rows = []
    ratios = (0.5,) if quick else (0.3, 0.5, 0.7)
    for gran in ("core", "reticle", "wafer"):
        for ratio in ratios:
            # homogeneous fallback at core level uses the decode design for
            # both stages (same reticle); hetero at reticle/wafer level mixes
            dp = d_decode if gran == "core" else d_prefill
            h = evaluate_hetero(dp, d_decode, wl, gran, ratio,
                                out_tokens=2048, n_wafers=8)
            rows.append({"granularity": gran, "prefill_ratio": ratio,
                         "speedup": h.throughput / gpu_t,
                         "kv_transfer_s": h.kv_transfer_s})
    # homogeneous reference: decode-tuned design for both stages, no split
    h0 = evaluate_hetero(d_decode, d_decode, wl, "reticle", 0.5,
                         out_tokens=2048, n_wafers=8)
    out = {"rows": rows, "homogeneous_speedup": h0.throughput / gpu_t}
    best = max(rows, key=lambda r: r["speedup"])
    out["best"] = best
    save_artifact("fig12_heterogeneity", out)
    print("\n=== Fig.12: heterogeneity (GPT-175B inference) ===")
    print(f"{'granularity':12s}{'ratio':>7s}{'speedup':>9s}{'kv_s':>10s}")
    for r in rows:
        print(f"{r['granularity']:12s}{r['prefill_ratio']:7.1f}"
              f"{r['speedup']:9.2f}{r['kv_transfer_s']:10.4f}")
    print(f"best: {best['granularity']} @ ratio {best['prefill_ratio']} "
          f"-> {best['speedup']:.2f}x (paper Takeaway 5: reticle-level wins)")
    return out


if __name__ == "__main__":
    run()
